"""Compare the paper's four LET-exchange protocols on one problem.

    PYTHONPATH=src python examples/fmm_protocols.py

Prints the Table-2/Fig-7-style accounting: stages, messages, wire bytes,
relay factor and LogGP model time per protocol, for a boundary (sphere)
distribution under hybrid-ORB partitioning.
"""
import numpy as np

from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution
from repro.core.protocols import PROTOCOLS


def main():
    n, nparts = 4000, 8
    x = make_distribution("sphere", n, seed=1)
    q = np.ones(n) / n
    print(f"{'protocol':<12}{'stages':>7}{'msgs':>7}{'wire MB':>9}"
          f"{'relay':>7}{'LogGP ms':>10}")
    phi = {}
    for proto in PROTOCOLS:
        res = run_distributed_fmm(x, q, nparts=nparts, method="orb",
                                  protocol=proto)
        st = res.schedule_stats
        phi[proto] = res.phi
        print(f"{proto:<12}{res.n_stages:>7}{st['n_msgs']:>7}"
              f"{st['wire_bytes']/1e6:>9.2f}{st['relay_factor']:>7.2f}"
              f"{res.loggp_time*1e3:>10.3f}")
    # all protocols compute the identical potential
    for proto in PROTOCOLS[1:]:
        np.testing.assert_allclose(phi[proto], phi[PROTOCOLS[0]], rtol=1e-12)
    print("all protocols delivered identical results")


if __name__ == "__main__":
    main()
