"""Compare the paper's four LET-exchange protocols on one problem.

    PYTHONPATH=src python examples/fmm_protocols.py

One `FMMSession` plans the geometry once (partitioning, local trees, batched
LET extraction, receiver traversals) and `sweep()` answers every protocol
from that single `GeometryPlan` — the potential is evaluated once and shared;
only the cheap communication schedules differ.  Prints the Table-2/Fig-7
style accounting: stages, messages, wire bytes, relay factor and LogGP model
time per protocol, for a boundary (sphere) distribution under hybrid-ORB
partitioning.
"""
import numpy as np

from repro.core.api import FMMSession, PartitionSpec
from repro.core.distributions import make_distribution
from repro.core.protocols import PROTOCOLS


def main():
    n, nparts = 4000, 8
    x = make_distribution("sphere", n, seed=1)
    q = np.ones(n) / n
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=nparts,
                                                      method="orb"))
    sweep = sess.sweep()
    print(f"{'protocol':<12}{'stages':>7}{'msgs':>7}{'wire MB':>9}"
          f"{'relay':>7}{'LogGP ms':>10}")
    for name in PROTOCOLS:
        res = sweep[name]
        st = res.schedule_stats
        print(f"{name:<12}{res.n_stages:>7}{st['n_msgs']:>7}"
              f"{st['wire_bytes']/1e6:>9.2f}{st['relay_factor']:>7.2f}"
              f"{res.loggp_time*1e3:>10.3f}")
    # every protocol delivered a schedule over the same LET volume, and the
    # shared potential matches the O(N^2) direct oracle
    from repro.core.fmm import direct_potential
    phi = sweep[PROTOCOLS[0]].phi
    ref = direct_potential(x, q)
    err = np.linalg.norm(phi - ref) / np.linalg.norm(ref)
    assert err < 3e-3, err
    print("all protocols served from one GeometryPlan "
          f"({sess.memo.misses} device uploads; rel L2 vs direct {err:.2e})")


if __name__ == "__main__":
    main()
