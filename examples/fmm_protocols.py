"""Compare the paper's four LET-exchange protocols on one problem.

    PYTHONPATH=src python examples/fmm_protocols.py

One `FMMSession` plans the geometry once (partitioning, local trees, batched
LET extraction, receiver traversals) and `sweep()` answers every protocol
from that single `GeometryPlan` — the potential is evaluated once and shared;
only the cheap communication schedules differ.  Prints the Table-2/Fig-7
style accounting: stages, messages, wire bytes, relay factor and LogGP model
time per protocol, for a boundary (sphere) distribution under hybrid-ORB
partitioning.

Running multi-device on CPU
---------------------------
The modeled schedules above also execute as *real* collective programs
(`repro.core.dist`) when the session gets a mesh.  No accelerator is
needed: JAX splits the host CPU into virtual devices.  Either export the
flag before python starts::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/fmm_protocols.py

or call `repro.launch.mesh.host_device_mesh(4)` BEFORE the first jax
computation (it sets the same flag, and raises a clear RuntimeError if the
backend already initialized with fewer devices).  Then::

    from repro.launch.mesh import host_device_mesh
    mesh = host_device_mesh(4)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=8),
                                  mesh=mesh, dist_protocol="hsdx")
    phi = sess.evaluate()        # LET exchange runs over real wires
    print(sess.exchange_stats)   # measured moved/delivered bytes, rounds,
                                 # LogGP prediction for the same schedule

`dist_protocol` is one of "bulk" (one padded all_to_all), "grain"
(granularity-tuned ppermute rounds) or "hsdx" (hierarchical relay); all
three deliver bitwise-identical potentials to the single-device engine.
`main()` below runs the sweep when multiple devices are visible.

The streaming near-field knob
-----------------------------
`FMMSession(..., p2p_stream=True)` evaluates the leaf-leaf direct sum
through the unified stream table (`kernels/p2p_stream.py`): every P2P
width class concatenates into one tile grid whose source/target slabs are
gathered *inside* the kernel via double-buffered VMEM DMA, instead of one
XLA gather + launch per bucket.  The default (`p2p_stream=None`) turns it
on exactly when the backend is a TPU; on CPU the same table runs as one
XLA slab program when forced on (`use_kernels=False`), and geometries
whose bucket rows are not contiguous runs fall back to the gathered path
automatically.  See the "Streaming vs gathered P2P" paragraphs in
`core/plan.py` and ROADMAP.md for the selection and VMEM budget math.

The session flight recorder
---------------------------
Every tier is instrumented through `repro.obs`; turn it on before the
work you want recorded and read the result with one call::

    from repro import obs
    obs.configure(enabled=True)      # or REPRO_TRACE=1 in the environment
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=8), mesh=mesh)
    sess.evaluate()
    rep = sess.report()              # one structured dict
    rep["timings"]                   #   wall time per span (plan.*,
                                     #   engine.*, dist.evaluate, ...)
    rep["exchange"]["protocols"]     #   per-protocol measured exchange time
                                     #   vs LogGP -> "model_drift" (1.0 =
                                     #   the model still predicts the wire)
    rep["launches"]                  #   entry-computation counts per fused
                                     #   executable (warm evaluate == 1)
    rep["metrics"]["counters"]       #   memo/cache/donation/autotune counts

To see where the milliseconds went on a timeline, export the chrome
trace and load it in Perfetto::

    import json
    with open("trace.json", "w") as f:
        json.dump(obs.get_tracer().to_chrome_trace(), f)

then open https://ui.perfetto.dev (or chrome://tracing) and drop
`trace.json` onto it — spans appear as nested slices per thread, instant
events (autotune decisions, exchange probes, cache compiles) as markers.
`obs.configure(enabled=True, fences=True)` additionally fences span
boundaries with `block_until_ready`, so per-phase spans measure device
occupancy instead of async dispatch (leave it off to preserve the fused
path's single-launch pipelining).  `main()` below prints a per-protocol
drift line when tracing is on.
"""
import numpy as np

from repro.core.api import FMMSession, PartitionSpec
from repro.core.distributions import make_distribution
from repro.core.protocols import PROTOCOLS


def main():
    n, nparts = 4000, 8
    x = make_distribution("sphere", n, seed=1)
    q = np.ones(n) / n
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=nparts,
                                                      method="orb"))
    sweep = sess.sweep()
    print(f"{'protocol':<12}{'stages':>7}{'msgs':>7}{'wire MB':>9}"
          f"{'relay':>7}{'LogGP ms':>10}")
    for name in PROTOCOLS:
        res = sweep[name]
        st = res.schedule_stats
        print(f"{name:<12}{res.n_stages:>7}{st['n_msgs']:>7}"
              f"{st['wire_bytes']/1e6:>9.2f}{st['relay_factor']:>7.2f}"
              f"{res.loggp_time*1e3:>10.3f}")
    # every protocol delivered a schedule over the same LET volume, and the
    # shared potential matches the O(N^2) direct oracle
    from repro.core.fmm import direct_potential
    phi = sweep[PROTOCOLS[0]].phi
    ref = direct_potential(x, q)
    err = np.linalg.norm(phi - ref) / np.linalg.norm(ref)
    assert err < 3e-3, err
    print("all protocols served from one GeometryPlan "
          f"({sess.memo.misses} device uploads; rel L2 vs direct {err:.2e})")

    # --- real wires: with >1 visible device the exchange actually runs ----
    import jax
    ndev = jax.local_device_count()
    if ndev >= 2 and nparts % ndev == 0:
        from repro.launch.mesh import host_device_mesh
        mesh = host_device_mesh(ndev)
        for proto_name in ("bulk", "grain", "hsdx"):
            dsess = FMMSession(sess.geometry, mesh=mesh,
                               dist_protocol=proto_name)
            dphi = dsess.evaluate()
            st = dsess.exchange_stats
            ok = np.allclose(dphi, phi, rtol=1e-6, atol=2e-5)
            print(f"dist {proto_name:<6} D={ndev} rounds={st['n_rounds']:>2}"
                  f" moved={st['moved_bytes']/1e6:.3f}MB"
                  f" delivered={st['delivered_bytes']/1e6:.3f}MB"
                  f" parity={ok}")
        # flight recorder: measured exchange vs the LogGP model, one call
        from repro import obs
        if obs.enabled():
            dsess = FMMSession(sess.geometry, mesh=mesh)
            rep = dsess.report()       # measures exchanges when tracing is on
            for proto_name, st in rep["exchange"]["protocols"].items():
                print(f"drift {proto_name:<6}"
                      f" measured={st['measured_s']*1e3:.3f}ms"
                      f" loggp={st['loggp_s']*1e3:.3f}ms"
                      f" model_drift={st['model_drift']:.2f}")
        else:
            print("(REPRO_TRACE=1 adds measured-vs-LogGP model_drift via "
                  "session.report())")
    else:
        print(f"({ndev} visible device(s); export XLA_FLAGS="
              f"--xla_force_host_platform_device_count=4 before python to "
              f"run the LET exchange over real wires)")

    # --- resilience: inject a fault, watch the ladder absorb it -----------
    # A resilient session walks the degradation ladder (dist -> streaming
    # -> gathered -> xla_slab -> per_phase -> host f64 reference) instead
    # of raising: here the fused megakernel launch is killed with a
    # simulated RESOURCE_EXHAUSTED (the OOM an oversubscribed accelerator
    # raises), the session drops one rung, recomputes, and reports the
    # downgrade.  `REPRO_FAULTS="fused.launch:1"` arms the same plan from
    # the environment; `REPRO_RESILIENCE=1` flips the default on.
    import warnings
    from repro.resilience import inject_faults
    rsess = FMMSession(sess.geometry, engine=True, fused=True,
                       use_kernels=False, p2p_stream=False, resilience=True)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with inject_faults("fused.launch"):
            rphi = rsess.evaluate()
    blk = rsess.report()["resilience"]
    fb = blk["fallbacks"][0]
    assert np.allclose(rphi, phi, rtol=1e-6, atol=2e-5)
    print(f"chaos: killed {fb['site']} -> degraded {fb['from']!r} to "
          f"{fb['to']!r}, phi parity kept "
          f"(degraded={blk['degraded']}, rung={blk['rung']})")


if __name__ == "__main__":
    main()
