"""Quickstart: solve an N-body boundary problem with the distributed FMM.

    PYTHONPATH=src python examples/quickstart.py

Partitions a spherical *boundary* distribution (the paper's target workload)
with hybrid ORB, exchanges the LET with HSDX, and checks the potential
against the O(N^2) direct sum.
"""
import numpy as np

from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution
from repro.core.fmm import direct_potential


def main():
    n, nparts = 4000, 8
    x = make_distribution("sphere", n, seed=42)
    q = np.random.default_rng(0).uniform(-1, 1, n)

    res = run_distributed_fmm(x, q, nparts=nparts, method="orb",
                              protocol="hsdx", theta=0.5, ncrit=64)
    ref = direct_potential(x, q)
    err = np.linalg.norm(res.phi - ref) / np.linalg.norm(ref)

    print(f"N={n} particles on a sphere, {nparts} partitions (hybrid ORB)")
    print(f"rel. L2 error vs direct sum : {err:.2e}  (P=4 Cartesian, theta=0.5)")
    print(f"LET volume                  : {res.bytes_matrix.sum()/1e6:.2f} MB total")
    print(f"HSDX stages                 : {res.n_stages} "
          f"(adjacency degree max {res.adjacency_degree:.0f}, diameter {res.diameter})")
    st = res.schedule_stats
    print(f"messages                    : {st['n_msgs']} "
          f"(relay factor {st['relay_factor']:.2f})")
    print(f"LogGP time model            : {res.loggp_time*1e3:.2f} ms")
    assert err < 3e-3
    print("OK")


if __name__ == "__main__":
    main()
