"""End-to-end driver: train a ~360M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300          # full 360M
    PYTHONPATH=src python examples/train_lm.py --steps 60 --smoke   # CI-sized

Uses the real production substrate: synthetic deterministic data pipeline,
AdamW with fp32 master weights, remat, checkpoint every 50 steps with
restart-on-relaunch (kill it mid-run and run again to see the resume).
"""
import argparse

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    out = run("smollm-360m", smoke=args.smoke, steps=args.steps,
              batch=8 if args.smoke else 4, seq=64 if args.smoke else 512,
              ckpt_dir=args.ckpt_dir, ckpt_every=50, lr=3e-3 if args.smoke else 3e-4)
    print(f"final loss {out['final_loss']:.4f} over {args.steps} steps "
          f"({out['stragglers']} straggler steps)")


if __name__ == "__main__":
    main()
