"""Batched serving example: continuous batching with prefill + decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.parallel import Parallelism


def main():
    cfg = get_config("qwen3-0.6b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, B=4, S_max=96,
                         par=Parallelism(remat=False))

    rng = np.random.default_rng(0)
    for rid in range(6):                      # 6 requests > 4 slots: queueing
        plen = int(rng.integers(4, 12))
        engine.submit(Request(rid=rid, prompt=list(rng.integers(1, cfg.vocab, plen)),
                              max_new=8))
    finished = engine.run(max_steps=64)
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"request {r.rid}: prompt_len={len(r.prompt)} -> {r.out}")
    assert len(finished) >= 4
    print(f"OK — served {len(finished)} requests through 4 slots")


if __name__ == "__main__":
    main()
