"""Regression gate: a fresh `FMMSession.report()` vs pinned invariants.

    PYTHONPATH=src python -m repro.analysis.check_counters --out obs-artifacts

Builds a toy fused session plus a 4-virtual-device mesh session with
tracing enabled and checks the load-bearing counters the repo's guarantees
rest on (ISSUE 8 regression gate):

  1. warm fused evaluate is EXACTLY one entry-computation launch
     (`hlo_walk.count_entry_launches` over the compiled HLO);
  2. a second same-shape-class geometry triggers ZERO new XLA compilations
     (the executable-cache contract);
  3. the STREAMING near-field fused path (ISSUE 9) keeps both contracts:
     one entry launch with the kernel variant recorded in the executable
     key, and zero recompiles on a second same-shape-class geometry;
  4. every dist protocol's exchange program delivers exactly the
     rank-aggregated off-diagonal `GeometryPlan.bytes_matrix`;
  5. each protocol's `model_drift` (measured / LogGP exchange time) is
     finite and positive — the probe itself works;
  6. resilience invariants (ISSUE 10): resilience armed with no faults
     keeps the warm fused one-launch contract and a False `degraded` flag,
     and after a chaos drive every injected fault is either a counted
     fallback or a typed `ResilienceError` (the accounting identity).

Exits nonzero on any violation, printing each check; writes the full
`report()` JSON and the chrome trace as artifacts under `--out` so a CI
failure ships the evidence.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> int:
    # virtual devices must be configured before jax initializes a backend
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="directory for report JSON + chrome trace artifacts")
    ap.add_argument("--n", type=int, default=800)
    ap.add_argument("--nparts", type=int, default=8)
    args = ap.parse_args()

    import numpy as np
    import jax

    from repro import obs
    obs.configure(enabled=True)

    from repro.analysis.hlo_walk import count_entry_launches
    from repro.core.api import FMMSession, PartitionSpec, plan_geometry
    from repro.core.engine.exe_cache import ExecutableCache

    failures: list[str] = []

    def check(ok: bool, label: str) -> None:
        print(f"{'ok  ' if ok else 'FAIL'} {label}")
        if not ok:
            failures.append(label)

    rng = np.random.default_rng(11)
    x = rng.normal(size=(args.n, 3))
    q = rng.uniform(-1, 1, args.n)
    spec = PartitionSpec(nparts=args.nparts, method="orb", ncrit=64)

    # --- fused single-device invariants (private cache: isolated counters) -
    cache = ExecutableCache()
    sess = FMMSession(plan_geometry(x, q, spec), engine=True, fused=True,
                      use_kernels=False, exe_cache=cache)
    sess.evaluate()                       # cold: compile + launch
    sess.evaluate()                       # warm: must be 1 entry launch
    eng = sess.engine
    (entry, _tabs) = eng._entries[("evaluate",
                                   bool(jax.config.jax_enable_x64))]
    check(count_entry_launches(entry.hlo_text) == 1,
          "warm fused evaluate compiles to exactly 1 entry computation")

    misses0 = cache.misses
    sess2 = FMMSession(plan_geometry(x.copy(), q.copy(), spec), engine=True,
                       fused=True, use_kernels=False, exe_cache=cache)
    sess2.evaluate()
    check(cache.misses == misses0,
          "second same-shape-class geometry -> 0 new XLA compilations "
          f"(misses {misses0} -> {cache.misses})")

    # --- streaming near-field invariants (ISSUE 9 gate) --------------------
    scache = ExecutableCache()
    s1 = FMMSession(plan_geometry(x, q, spec), engine=True, fused=True,
                    use_kernels=False, p2p_stream=True, exe_cache=scache)
    s1.evaluate()
    s1.evaluate()
    (sentry, _stabs) = s1.engine._entries[("evaluate",
                                           bool(jax.config.jax_enable_x64))]
    check(count_entry_launches(sentry.hlo_text) == 1,
          "warm fused STREAMING evaluate compiles to exactly 1 entry "
          "computation")
    check(sentry.key[-1] == "stream",
          "streaming executable key records the kernel variant "
          f"(key[-1]={sentry.key[-1]!r})")
    smisses0 = scache.misses
    s2 = FMMSession(plan_geometry(x.copy(), q.copy(), spec), engine=True,
                    fused=True, use_kernels=False, p2p_stream=True,
                    exe_cache=scache)
    s2.evaluate()
    check(scache.misses == smisses0,
          "second same-shape-class geometry on the STREAMING path -> 0 new "
          f"XLA compilations (misses {smisses0} -> {scache.misses})")

    # --- mesh-backed exchange invariants -----------------------------------
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:4])
    if len(devs) < 4:
        print(f"note: only {len(devs)} device(s) visible; set XLA_FLAGS="
              "--xla_force_host_platform_device_count=4 before jax init")
    mesh = Mesh(devs, ("rk",))
    msess = FMMSession(plan_geometry(x, q, spec), mesh=mesh,
                       dist_protocol="bulk")
    rep = msess.report(measure_exchange=True, reps=2)

    geo = msess.geometry
    lay = msess.dist.layout
    expect = int(lay.rank_bytes.sum())      # zero diagonal by construction
    for name, st in rep["exchange"]["protocols"].items():
        check(st["delivered_bytes"] == expect,
              f"{name}: delivered_bytes {st['delivered_bytes']} == "
              f"rank off-diagonal bytes matrix {expect}")
        drift = st["model_drift"]
        check(np.isfinite(drift) and drift > 0,
              f"{name}: model_drift finite and positive ({drift:.3g})")
    inter = int(sum(geo.bytes_matrix[i, j]
                    for i in range(len(lay.part_rank))
                    for j in range(len(lay.part_rank))
                    if lay.part_rank[i] != lay.part_rank[j]))
    check(inter == expect,
          "rank_bytes aggregates GeometryPlan.bytes_matrix's inter-rank "
          f"entries exactly ({inter} == {expect})")

    # --- resilience invariants (ISSUE 10 gate) -----------------------------
    import warnings

    from repro.resilience import fallback as res_fb
    from repro.resilience import faults as res_faults
    from repro.resilience import ResilienceError, inject_faults

    res_faults.reset_stats()
    res_fb.reset_ledger()

    # 1. resilience armed with NO faults must not perturb the serving path:
    #    warm fused evaluate stays exactly one entry computation
    rcache = ExecutableCache()
    rsess = FMMSession(plan_geometry(x, q, spec), engine=True, fused=True,
                       use_kernels=False, exe_cache=rcache, resilience=True)
    rsess.evaluate()
    rsess.evaluate()
    (rentry, _rt) = rsess.engine._entries[("evaluate",
                                           bool(jax.config.jax_enable_x64))]
    check(count_entry_launches(rentry.hlo_text) == 1,
          "warm fused evaluate with resilience ENABLED (no faults) still "
          "compiles to exactly 1 entry computation")
    check(not rsess.resilience.degraded,
          "resilience enabled + no faults -> degraded flag stays False")
    check(res_faults.fired_total() == 0,
          "no armed plan -> zero faults fired")

    # 2. chaos accounting identity: drive a fallback AND a typed error, then
    #    every fired fault must be a counted fallback or a typed error
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        c1 = FMMSession(plan_geometry(x, q, spec), engine=True, fused=True,
                        use_kernels=False, exe_cache=ExecutableCache(),
                        resilience=True)
        with inject_faults("fused.launch"):
            c1.evaluate()
        check(c1.resilience.degraded
              and c1.resilience.fallbacks[0]["site"] == "fused.launch",
              "injected fused.launch RESOURCE_EXHAUSTED -> one counted "
              "ladder fallback")
        c2 = FMMSession(plan_geometry(x, q, spec), engine=False,
                        resilience=True)
        got_typed = False
        try:
            with inject_faults({"memo.upload": {"count": None}}):
                c2.evaluate()
        except ResilienceError as exc:
            got_typed = exc.site == "memo.upload"
        check(got_typed,
              "ladder exhaustion surfaces a typed ResilienceError naming "
              "the site")
    fired = res_faults.fired_total()
    absorbed = res_fb.fallback_total() + res_fb.typed_error_total()
    check(fired > 0 and fired == absorbed,
          f"chaos accounting: injected faults ({fired}) == counted "
          f"fallbacks + typed errors ({absorbed})")

    # --- artifacts ---------------------------------------------------------
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        rep_path = os.path.join(args.out, "session_report.json")
        with open(rep_path, "w") as fh:
            json.dump(rep, fh, indent=1, sort_keys=True, default=str)
        tracer = obs.get_tracer()
        trace_path = os.path.join(args.out, "session_trace.json")
        with open(trace_path, "w") as fh:
            json.dump(tracer.to_chrome_trace(), fh, default=str)
        print(f"wrote {rep_path} and {trace_path}")

    if failures:
        print(f"\n{len(failures)} invariant violation(s)")
        return 1
    print("\nall counter invariants hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
