"""Trip-count-aware HLO walker.

XLA's cost_analysis counts every computation ONCE — a scanned 32-layer stack
reports 1/32 of the real FLOPs, and FSDP all-gathers inside the loop body are
similarly undercounted.  This walker parses the post-partitioning HLO text,
recovers while-loop trip counts from their condition computations, propagates
multipliers down the call graph (while bodies, fusions, calls), and sums

  - collective result bytes  (all-gather/all-reduce/reduce-scatter/
    all-to-all/collective-permute), and
  - dot FLOPs  (2 * prod(result_dims) * contracted_size),

each weighted by how many times its computation actually executes.
Shapes in the partitioned module are per-device, so totals are per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_list(s: str):
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(shapes):
    tot = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


def parse_computations(txt: str) -> dict:
    """name -> list of instruction lines."""
    comps = {}
    cur = None
    for line in txt.splitlines():
        # headers may contain nested parens (tuple-typed params) — match
        # greedily on the one-line "name (args) -> result {" form
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line.strip())
    return comps


def _entry_name(txt: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M)
    return m.group(1) if m else None


def count_entry_launches(txt: str) -> int:
    """Number of ENTRY computations in (possibly concatenated) compiled HLO
    text — the dispatch count a warm caller pays: every compiled executable
    has exactly one ENTRY, so a pipeline's launch count is the ENTRY count
    over its executables' HLO.  Counts only ENTRY headers that parse as real
    computations (`parse_computations`), so stray 'ENTRY' tokens in operand
    metadata never inflate the result.  The fused-engine tests pin warm
    evaluate()/step() at exactly 1.

    NOTE: feed `compiled.as_text()` (post-compilation HLO).  `lowered
    .as_text()` is StableHLO, which has no ENTRY headers and counts as 0."""
    comps = parse_computations(txt)
    entries = re.findall(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M)
    return sum(1 for e in entries if e in comps)


def _trip_count(cond_lines) -> int:
    """Largest integer constant in the while condition ~= trip bound."""
    best = 1
    for ln in cond_lines:
        for c in re.findall(r"constant\((\d+)\)", ln):
            best = max(best, int(c))
    return best


def _called(line: str):
    """Computations invoked by this instruction: (name, multiplier_kind)."""
    out = []
    m = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", line)
    if m:
        out.append((m.group(2), "while_body"))
        out.append((m.group(1), "while_cond"))
        return out
    is_fusion = " fusion(" in line
    for key in ("calls=", "to_apply=", "true_computation=", "false_computation=",
                "branch_computations={"):
        idx = line.find(key)
        if idx >= 0:
            seg = line[idx + len(key):]
            names = re.findall(r"%?([\w.\-]+)", seg.split("}")[0] if "{" in key
                               else seg.split(",")[0].split(")")[0])
            out.extend((n, "fusion" if is_fusion else "call") for n in names[:4] if n)
    return out


def compute_multipliers(txt: str):
    """Returns (multiplier map, fusion-internal set)."""
    comps = parse_computations(txt)
    entry = _entry_name(txt)
    mult = defaultdict(float)
    fusion_internal = set()
    if entry is None:
        return {name: 1.0 for name in comps}, fusion_internal
    stack = [(entry, 1.0, False)]
    seen_pairs = set()
    while stack:
        name, m, in_fusion = stack.pop()
        if name not in comps:
            continue
        mult[name] += m
        if in_fusion:
            fusion_internal.add(name)
        for ln in comps[name]:
            for callee, kind in _called(ln):
                if callee not in comps:
                    continue
                if kind == "while_body":
                    cond = re.search(r"condition=%?([\w.\-]+)", ln).group(1)
                    trips = _trip_count(comps.get(cond, []))
                    child_m = m * trips
                else:
                    child_m = m
                key = (name, callee, kind, id(ln))
                if key in seen_pairs:
                    continue
                seen_pairs.add(key)
                stack.append((callee, child_m,
                              in_fusion or kind == "fusion"))
    return dict(mult), fusion_internal


def _crosses_pod(line: str, pod_size: int) -> bool:
    """Does this collective's replica_groups span pod boundaries?"""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
                  line)
    if m:
        g, k = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        n = 1
        for d in dims:
            n *= d
        try:
            import numpy as _np
            ids = _np.arange(n).reshape(dims)
            if m.group(4):
                perm = [int(p) for p in m.group(4).split(",")]
                ids = ids.transpose(perm)
            ids = ids.reshape(g, k)
            return bool((ids // pod_size != ids[:, :1] // pod_size).any())
        except Exception:
            return True
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        ids = [int(v) for v in m.group(1).split(",")]
        return len({i // pod_size for i in ids}) > 1
    return False


def weighted_analysis(txt: str, pod_size: int = 256) -> dict:
    """Per-device collective bytes, dot FLOPs and result bytes (HBM-write
    proxy), all trip-count weighted.  Collective bytes are also split into
    intra-pod vs inter-pod (replica groups crossing `pod_size` boundaries)."""
    comps = parse_computations(txt)
    mult, fusion_internal = compute_multipliers(txt)
    coll_bytes = defaultdict(float)
    coll_counts = defaultdict(float)
    inter_pod_bytes = 0.0
    intra_pod_bytes = 0.0
    dot_flops = 0.0
    result_bytes = 0.0

    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        count_bytes = name not in fusion_internal
        # map of instruction name -> result shapes (for dot operand lookup)
        shapes = {}
        for ln in lines:
            mm = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", ln)
            if not mm:
                continue
            iname, rhs = mm.group(1), mm.group(2)
            op_end = rhs.find("(")
            header = rhs[:op_end] if op_end > 0 else rhs
            shapes[iname] = _shape_list(header)
        for ln in lines:
            mm = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", ln)
            if not mm:
                continue
            rhs = mm.group(2)
            if "-done(" in rhs:
                continue
            if count_bytes and " parameter(" not in rhs:
                op_end = rhs.find("(")
                header = rhs[:op_end] if op_end > 0 else rhs
                op = header.split()[-1] if op_end > 0 else ""
                # only ops that genuinely write HBM on TPU: tuple plumbing
                # (get-tuple-element etc.) is free, fusions/dots are not
                if op in ("fusion", "dot", "copy", "convert", "reduce",
                          "scatter", "gather", "dynamic-slice",
                          "dynamic-update-slice", "concatenate", "transpose",
                          "convolution", "reduce-window", "iota", "reverse",
                          "pad", "slice"):
                    result_bytes += _nbytes(_shape_list(header)) * m
            for cname in _COLLECTIVES:
                if re.search(rf"\b{cname}(-start)?\(", rhs):
                    header = rhs.split(cname)[0]
                    b = _nbytes(_shape_list(header))
                    coll_bytes[cname] += b * m
                    coll_counts[cname] += m
                    if _crosses_pod(rhs, pod_size):
                        inter_pod_bytes += b * m
                    else:
                        intra_pod_bytes += b * m
                    break
            dm = re.search(r"\bdot\(([^)]*)\)", rhs)
            if dm:
                header = rhs.split(" dot(")[0]
                res_shapes = _shape_list(header)
                if not res_shapes:
                    continue
                res_elems = 1
                for d in res_shapes[0][1]:
                    res_elems *= d
                # contracted size from lhs operand shape + contracting dims.
                # Some XLA versions print typed operands inline
                # (dot(f32[128,512] %a, ...)); others print bare names — try
                # the inline shapes first, then the name -> shape map.
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                arg_shapes = _shape_list(dm.group(1))
                lshape = arg_shapes[0][1] if arg_shapes else None
                if lshape is None:
                    ops = [o.strip().lstrip("%")
                           for o in dm.group(1).split(",")[:2]]
                    if ops and ops[0] in shapes and shapes[ops[0]]:
                        lshape = shapes[ops[0]][0][1]
                csize = 1
                if cdims and lshape is not None:
                    for d in cdims.group(1).split(","):
                        if d and int(d) < len(lshape):
                            csize *= lshape[int(d)]
                dot_flops += 2.0 * res_elems * csize * m
    return {
        "collective_bytes": dict(coll_bytes),
        "collective_counts": dict(coll_counts),
        "total_collective_bytes": sum(coll_bytes.values()),
        "inter_pod_bytes": inter_pod_bytes,
        "intra_pod_bytes": intra_pod_bytes,
        "dot_flops": dot_flops,
        "result_bytes": result_bytes,
    }
