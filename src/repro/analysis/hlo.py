"""Post-partitioning HLO analysis: collective bytes per category.

cost_analysis() gives FLOPs and memory bytes but NOT collective traffic; we
parse the compiled module text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.  Shapes in the
post-SPMD module are PER-DEVICE, so the sums are per-device wire bytes.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,1024,128]{...}" — first shape on the line is the result
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+\s*=\s*(?:\(([^)]*)\)|(\S+?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective category (result sizes).
    -start/-done pairs are counted once (the -start carries the shape)."""
    out = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.lstrip()
        if "-done(" in stripped:
            continue  # counted at -start
        m = re.match(r"^(?:ROOT\s+)?%?\S+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for cname in _COLLECTIVES:
            # match "<shape> <collective>(" or "(<tuple shapes>) <collective>("
            idx = rhs.find(f" {cname}(")
            if idx < 0:
                idx = rhs.find(f") {cname}(")
                if idx >= 0:
                    idx += 1
            if idx >= 0:
                shape_part = rhs[:idx]
                b = _shape_bytes(shape_part)
                out[cname] += b
                counts[cname] += 1
                break
    return {"bytes": dict(out), "counts": dict(counts),
            "total_bytes": sum(out.values())}
