"""Generate EXPERIMENTS.md sections (§Dry-run, §Roofline) from artifacts.

    PYTHONPATH=src python -m repro.analysis.report --out EXPERIMENTS.md
"""
from __future__ import annotations

import argparse
import json
import os

from repro.analysis.roofline import (PEAK_FLOPS, roofline_from_artifact)


def load(art_dir):
    recs = []
    for f in sorted(os.listdir(art_dir)):
        if f.endswith(".json"):
            with open(os.path.join(art_dir, f)) as fh:
                d = json.load(fh)
            d["_file"] = f
            recs.append(d)
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(recs, pod):
    lines = [
        "| arch | shape | status | compile s | args GB/dev | temp GB/dev | "
        "coll GB/dev | n_micro |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if f"__{pod}.json" != r["_file"].split("__", 2)[-1][len(r['shape']) + 2:] \
                and not r["_file"].endswith(f"__{pod}.json"):
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['skipped'][:40]}…) "
                         "| – | – | – | – | – |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | – | – | – | – | – |")
            continue
        m = r["memory"]
        w = r.get("walked", {})
        coll = w.get("total_collective_bytes", r["collectives"]["total_bytes"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{fmt_bytes(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(m.get('temp_size_in_bytes', 0))} | "
            f"{fmt_bytes(coll)} | {r.get('n_micro', '–')} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant | "
        "roofline frac | useful ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    advice = {
        ("moe", "collective"): "hierarchical/two-stage a2a; larger grain",
        ("moe", "memory"): "sequence-parallel activations; lower capacity factor",
        ("moe", "compute"): "kernel fusion (Pallas attention) on device",
        ("dense", "memory"): "fused attention kernel keeps tiles in VMEM; "
                             "sequence-parallel residuals",
        ("dense", "collective"): "chunked ring all-gather overlapped with matmul",
        ("dense", "compute"): "already compute-bound — tune MXU tiling",
    }
    rows = []
    for r in recs:
        if not r["_file"].endswith("__1pod.json"):
            continue
        if "skipped" in r or "error" in r:
            continue
        w = r.get("walked", {})
        rr = roofline_from_artifact(r, w if "dot_flops" in w else None)
        rows.append((r, rr))
    rows.sort(key=lambda t: (t[0]["arch"], t[0]["shape"]))
    from repro.configs import get_config
    for r, rr in rows:
        fam = get_config(r["arch"]).family
        fam_key = "moe" if fam == "moe" else "dense"
        hint = advice.get((fam_key, rr["dominant"]), "overlap/shard the dominant mover")
        lines.append(
            f"| {rr['arch']} | {rr['shape']} | {rr['compute_s']*1e3:.2f} | "
            f"{rr['memory_s']*1e3:.2f} | {rr['collective_s']*1e3:.2f} | "
            f"{rr['dominant']} | {rr['roofline_fraction']:.3f} | "
            f"{min(rr['useful_ratio'], 99.0):.2f} | {hint} |")
    return "\n".join(lines)


def observability_section(rep: dict) -> str:
    """§Observability markdown from a `FMMSession.report()` dict (or a JSON
    file of one, e.g. the artifact `analysis/check_counters.py` writes)."""
    lines = ["## §Observability — session flight recorder\n"]
    o = rep.get("obs", {})
    lines.append(f"tracing: {'on' if o.get('enabled') else 'off'}"
                 f" · fences: {'on' if o.get('fences') else 'off'}"
                 f" · events: {o.get('events', 0)}"
                 f" · dropped: {o.get('dropped', 0)}\n")
    timings = rep.get("timings", {})
    if timings:
        lines.append("| span | count | total ms | mean ms | max ms |")
        lines.append("|---|---|---|---|---|")
        for name in sorted(timings, key=lambda k: -timings[k]["total_s"]):
            t = timings[name]
            lines.append(f"| {name} | {t['count']} | {t['total_s']*1e3:.3f} "
                         f"| {t['mean_s']*1e3:.3f} | {t['max_s']*1e3:.3f} |")
        lines.append("")
    ex = rep.get("exchange", {})
    if ex.get("enabled") and ex.get("protocols"):
        lines.append("| protocol | rounds | moved bytes | loggp ms "
                     "| measured ms | model drift |")
        lines.append("|---|---|---|---|---|---|")
        for name, st in ex["protocols"].items():
            meas = st.get("measured_s")
            drift = st.get("model_drift")
            loggp = st.get("loggp_s", st.get("loggp_time", 0.0))
            meas_c = f"{meas*1e3:.3f}" if meas is not None else "–"
            drift_c = f"{drift:.2f}" if drift is not None else "–"
            lines.append(f"| {name} | {st.get('n_rounds', '–')} "
                         f"| {st.get('moved_bytes', '–')} | {loggp*1e3:.3f} "
                         f"| {meas_c} | {drift_c} |")
        lines.append("")
    counters = rep.get("metrics", {}).get("counters", {})
    if counters:
        lines.append("counters: "
                     + " · ".join(f"{k}={int(v)}"
                                  for k, v in sorted(counters.items())))
        lines.append("")
    ec = rep.get("exe_cache", {})
    if ec:
        lines.append(f"exe_cache: hits={ec.get('hits')} "
                     f"misses={ec.get('misses')} "
                     f"evictions={ec.get('evictions')} "
                     f"size={ec.get('size')}")
    la = rep.get("launches", {})
    if la and la.get("enabled", True):
        for kind, d in la.items():
            if not isinstance(d, dict):
                continue
            lines.append(f"launches[{kind}]: calls={d['calls']} "
                         f"entry_computations={d['entry_computations']}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts")
    ap.add_argument("--section", default="all")
    ap.add_argument("--obs", default=None,
                    help="path to a FMMSession.report() JSON; renders the "
                         "§Observability section from it")
    args = ap.parse_args()
    if args.obs:
        with open(args.obs) as fh:
            print(observability_section(json.load(fh)))
        if args.section == "obs":
            return
    recs = load(args.artifacts)
    print("## §Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "1pod"))
    print("\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "2pod"))
    print("\n## §Roofline — single pod, per (arch x shape)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
