"""Generate EXPERIMENTS.md sections (§Dry-run, §Roofline) from artifacts.

    PYTHONPATH=src python -m repro.analysis.report --out EXPERIMENTS.md
"""
from __future__ import annotations

import argparse
import json
import os

from repro.analysis.roofline import (PEAK_FLOPS, roofline_from_artifact)


def load(art_dir):
    recs = []
    for f in sorted(os.listdir(art_dir)):
        if f.endswith(".json"):
            with open(os.path.join(art_dir, f)) as fh:
                d = json.load(fh)
            d["_file"] = f
            recs.append(d)
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(recs, pod):
    lines = [
        "| arch | shape | status | compile s | args GB/dev | temp GB/dev | "
        "coll GB/dev | n_micro |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if f"__{pod}.json" != r["_file"].split("__", 2)[-1][len(r['shape']) + 2:] \
                and not r["_file"].endswith(f"__{pod}.json"):
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['skipped'][:40]}…) "
                         "| – | – | – | – | – |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | – | – | – | – | – |")
            continue
        m = r["memory"]
        w = r.get("walked", {})
        coll = w.get("total_collective_bytes", r["collectives"]["total_bytes"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{fmt_bytes(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(m.get('temp_size_in_bytes', 0))} | "
            f"{fmt_bytes(coll)} | {r.get('n_micro', '–')} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant | "
        "roofline frac | useful ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    advice = {
        ("moe", "collective"): "hierarchical/two-stage a2a; larger grain",
        ("moe", "memory"): "sequence-parallel activations; lower capacity factor",
        ("moe", "compute"): "kernel fusion (Pallas attention) on device",
        ("dense", "memory"): "fused attention kernel keeps tiles in VMEM; "
                             "sequence-parallel residuals",
        ("dense", "collective"): "chunked ring all-gather overlapped with matmul",
        ("dense", "compute"): "already compute-bound — tune MXU tiling",
    }
    rows = []
    for r in recs:
        if not r["_file"].endswith("__1pod.json"):
            continue
        if "skipped" in r or "error" in r:
            continue
        w = r.get("walked", {})
        rr = roofline_from_artifact(r, w if "dot_flops" in w else None)
        rows.append((r, rr))
    rows.sort(key=lambda t: (t[0]["arch"], t[0]["shape"]))
    from repro.configs import get_config
    for r, rr in rows:
        fam = get_config(r["arch"]).family
        fam_key = "moe" if fam == "moe" else "dense"
        hint = advice.get((fam_key, rr["dominant"]), "overlap/shard the dominant mover")
        lines.append(
            f"| {rr['arch']} | {rr['shape']} | {rr['compute_s']*1e3:.2f} | "
            f"{rr['memory_s']*1e3:.2f} | {rr['collective_s']*1e3:.2f} | "
            f"{rr['dominant']} | {rr['roofline_fraction']:.3f} | "
            f"{min(rr['useful_ratio'], 99.0):.2f} | {hint} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts")
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    recs = load(args.artifacts)
    print("## §Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "1pod"))
    print("\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "2pod"))
    print("\n## §Roofline — single pod, per (arch x shape)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
