"""Three-term roofline from dry-run artifacts (TPU v5e targets).

    compute term    = FLOPs_per_device / peak_FLOPs
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / (links * link_bw)

FLOPs source: trip-count-corrected dot FLOPs walked from the compiled HLO
(analysis.hlo_walk) — XLA's cost_analysis counts while bodies once, so the
raw number is also recorded for comparison.  Memory bytes: 2x the weighted
top-level result bytes (reads ~ writes) from the same walk.  Collective
bytes: weighted result sizes of all-gather/all-reduce/reduce-scatter/
all-to-all/collective-permute (per-device, post-partitioning shapes).

MODEL_FLOPS = 6 N D (train) / 2 N D (inference) per token with N = active
params; the ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute
is "useful" (remat recompute, capacity-factor waste, causal-mask overcount
all show up here).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link
N_LINKS = 3                  # usable links/chip on a v5e 2D torus (conservative)


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def fraction_of_roofline(self) -> float:
        """compute_time / bound_time: 1.0 = perfectly compute-bound."""
        return self.compute_s / max(self.bound_s, 1e-30)


def model_flops_per_step(rec: dict) -> float:
    """6*N_active*D for train, 2*N_active*D for inference (whole step,
    all devices)."""
    n_act = rec["active_params"]
    shape = rec["shape"]
    from repro.configs import SHAPES
    sh = SHAPES[shape]
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_act * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_act * tokens
    tokens = sh.global_batch          # one token per sequence
    return 2.0 * n_act * tokens


def roofline_from_artifact(rec: dict, walked: dict | None = None) -> dict:
    n_chips = 1
    for d in rec["mesh"]:
        n_chips *= d
    if walked is not None:
        flops_dev = walked["dot_flops"]
        mem_dev = walked.get("result_bytes", 0.0) * 2.0
        coll_dev = walked["total_collective_bytes"]
    else:
        flops_dev = rec.get("flops") or 0.0
        mem_dev = rec.get("bytes_accessed") or 0.0
        coll_dev = rec["collectives"]["total_bytes"]
    r = Roofline(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=mem_dev / HBM_BW,
        collective_s=coll_dev / (N_LINKS * LINK_BW),
    )
    mflops = model_flops_per_step(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "x".join(map(str, rec["mesh"])),
        "compute_s": r.compute_s, "memory_s": r.memory_s,
        "collective_s": r.collective_s, "dominant": r.dominant,
        "bound_s": r.bound_s,
        "roofline_fraction": r.fraction_of_roofline,
        "model_flops": mflops,
        "hlo_flops_dev": flops_dev,
        "useful_ratio": mflops / max(flops_dev * n_chips, 1e-30),
        "collective_GB_dev": coll_dev / 1e9,
        "mem_GB_args": rec["memory"].get("argument_size_in_bytes", 0) / 1e9,
        "mem_GB_temp": rec["memory"].get("temp_size_in_bytes", 0) / 1e9,
    }


def load_artifacts(art_dir: str, pattern: str = "") -> list[dict]:
    out = []
    for f in sorted(os.listdir(art_dir)):
        if not f.endswith(".json") or pattern not in f:
            continue
        with open(os.path.join(art_dir, f)) as fh:
            out.append(json.load(fh))
    return out
