"""Model facade: one entry point per workload kind for every architecture."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode as decode_mod
from repro.models import transformer as tf
from repro.models.params import (init_params, param_shardings, param_structs)
from repro.sharding.parallel import Parallelism


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters -----------------------------------------------------
    def defs(self):
        return tf.model_defs(self.cfg)

    def init(self, key):
        return init_params(self.defs(), key)

    def param_structs(self):
        return param_structs(self.defs())

    def param_shardings(self, mesh, fsdp_pod: bool = False):
        return param_shardings(self.defs(), mesh, fsdp_pod=fsdp_pod)

    # ---- compute --------------------------------------------------------
    def loss(self, params, batch, par: Parallelism, chunked: bool = False):
        return tf.loss_fn(params, batch, self.cfg, par, chunked=chunked)

    def forward(self, params, batch, par: Parallelism, chunked: bool = False):
        return tf.forward(params, batch["tokens"], self.cfg, par,
                          frames=batch.get("frames"), vis=batch.get("vis"),
                          chunked=chunked)

    def prefill(self, params, batch, par: Parallelism, S_max: int):
        return decode_mod.prefill(params, batch, self.cfg, par, S_max)

    def decode_step(self, params, cache, tokens, pos, par: Parallelism):
        return decode_mod.decode_step(params, cache, tokens, pos, self.cfg, par)

    def init_cache(self, B: int, S_max: int):
        return decode_mod.init_cache(self.cfg, B, S_max)

    def cache_struct(self, B: int, S_max: int):
        return decode_mod.cache_struct(self.cfg, B, S_max)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
