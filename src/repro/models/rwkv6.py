"""RWKV6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

The WKV recurrence runs *chunkwise* in pure JAX (TPU-native: within a chunk
the recurrence factorizes into two MXU matmuls plus a masked intra-chunk
product; the O(Dk x Dv) state crosses chunks in a lax.scan).  The Pallas
kernel (kernels/rwkv.py) is the fused in-VMEM variant of the same math.
Attention-free: decode state is O(D^2/H) per layer — no KV cache at all,
which is what makes the long_500k cell trivial for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.models.params import ParamDef


def rwkv_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "ln2": ParamDef((d,), (None,), init="ones"),
        # time-mix
        "mu_r": ParamDef((d,), (None,), init="zeros"),
        "mu_k": ParamDef((d,), (None,), init="zeros"),
        "mu_v": ParamDef((d,), (None,), init="zeros"),
        "mu_w": ParamDef((d,), (None,), init="zeros"),
        "mu_g": ParamDef((d,), (None,), init="zeros"),
        "w_r": ParamDef((d, d), ("data", "model")),
        "w_k": ParamDef((d, d), ("data", "model")),
        "w_v": ParamDef((d, d), ("data", "model")),
        "w_w": ParamDef((d, d), ("data", "model"), scale=1e-2),
        "w_g": ParamDef((d, d), ("data", "model")),
        "w_o": ParamDef((d, d), ("model", "data")),
        "w_bias": ParamDef((d,), (None,), init="zeros"),
        "u_bonus": ParamDef((d,), (None,), init="zeros"),
        "ln_x": ParamDef((d,), (None,), init="ones"),
        # channel-mix
        "cmu_k": ParamDef((d,), (None,), init="zeros"),
        "cmu_r": ParamDef((d,), (None,), init="zeros"),
        "cw_k": ParamDef((d, f), ("data", "model")),
        "cw_v": ParamDef((f, d), ("model", "data")),
        "cw_r": ParamDef((d, d), ("data", "model")),
    }


def _token_shift(x, prev):
    """prev: (B, 1, D) last token of the previous segment (or zeros)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunkwise WKV.  r/k/v: (B, H, S, hd); w: decay in (0,1); u: (H, hd);
    state: (B, H, hd, hd).  Returns (y, state')."""
    B, H, S, D = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-5, 1.0))

    def split(a):
        return jnp.moveaxis(a.reshape(B, H, nc, chunk, D), 2, 0)

    rc, kc, vc, lwc = split(r.astype(jnp.float32)), split(k.astype(jnp.float32)), \
        split(v.astype(jnp.float32)), split(logw)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)    # strictly lower

    def step(s, xs):
        rt, kt, vt, lw = xs                                      # (B,H,C,D)
        cs = jnp.cumsum(lw, axis=2)                              # cum log decay
        cs_prev = cs - lw                                        # up to t-1
        r_in = rt * jnp.exp(cs_prev)                             # A_{t-1} weight
        k_out = kt * jnp.exp(-cs)                                # 1/A_s weight
        # inter-chunk: y_inter = (r * A_{t-1}) @ S
        y = jnp.einsum("bhtd,bhde->bhte", r_in, s)
        # intra-chunk strictly-causal term
        att = jnp.einsum("bhtd,bhsd->bhts", r_in, k_out) * tri[None, None]
        y = y + jnp.einsum("bhts,bhse->bhte", att, vt)
        # bonus diagonal term
        y = y + jnp.einsum("bhtd,bhtd->bht", rt, u[None, :, None] * kt)[..., None] * vt
        # state update: S' = exp(cs_C) S + sum_s exp(cs_C - cs_s) k_s v_s^T
        decay_all = jnp.exp(cs[:, :, -1:, :])                    # (B,H,1,D)
        k_scaled = kt * jnp.exp(cs[:, :, -1:, :] - cs)
        s = decay_all[:, :, 0, :, None] * s + jnp.einsum("bhsd,bhse->bhde",
                                                         k_scaled, vt)
        return s, y

    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (rc, kc, vc, lwc))
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, S, D)
    return y.astype(r.dtype), state


def time_mix(x, p, cfg, prev_tok=None, wkv_state=None):
    """x: (B, S, D).  Returns (out, (last_token, wkv_state))."""
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    prev = prev_tok if prev_tok is not None else jnp.zeros((B, 1, D), x.dtype)
    xs = _token_shift(x, prev)

    def mix(mu):
        return x + (xs - x) * mu

    r = (mix(p["mu_r"]) @ p["w_r"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (mix(p["mu_k"]) @ p["w_k"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (mix(p["mu_v"]) @ p["w_v"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["w_g"])
    w = jnp.exp(-jnp.exp((mix(p["mu_w"]) @ p["w_w"] + p["w_bias"])
                         .astype(jnp.float32)))                  # (B,S,D) in (0,1)
    w = w.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    u = p["u_bonus"].reshape(H, hd)
    s0 = wkv_state if wkv_state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
    y, s1 = wkv_chunked(r, k, v, w, u, s0)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, D)
    y = rms_norm(y, p["ln_x"], 1e-5) * g
    return y @ p["w_o"], (x[:, -1:], s1)


def channel_mix(x, p, prev_tok=None):
    B, S, D = x.shape
    prev = prev_tok if prev_tok is not None else jnp.zeros((B, 1, D), x.dtype)
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["cmu_k"]
    xr = x + (xs - x) * p["cmu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cw_k"]))
    return jax.nn.sigmoid(xr @ p["cw_r"]) * (k @ p["cw_v"]), x[:, -1:]


def rwkv_block(x, p, cfg, cache=None):
    """cache: dict(tm_tok, wkv, cm_tok) or None.  Returns (x, new_cache)."""
    tm_tok = cache["tm_tok"] if cache else None
    wkv = cache["wkv"] if cache else None
    cm_tok = cache["cm_tok"] if cache else None
    h, (tm_tok_n, wkv_n) = time_mix(rms_norm(x, p["ln1"]), p, cfg, tm_tok, wkv)
    x = x + h
    h, cm_tok_n = channel_mix(rms_norm(x, p["ln2"]), p, cm_tok)
    x = x + h
    return x, {"tm_tok": tm_tok_n, "wkv": wkv_n, "cm_tok": cm_tok_n}
