"""Selective SSM (Mamba-style) head for hymba's hybrid layers.

Diagonal selective state space:  h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t,
y_t = C_t . h_t + D x_t, with dt/B/C data-dependent.  Time is processed in
chunks (lax.scan carrying h) with an associative scan inside each chunk —
O(chunk) live memory, sub-quadratic in S (this is what qualifies hymba for
the long_500k shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


def ssm_defs(cfg):
    d, n = cfg.d_model, cfg.ssm_state
    return {
        "in_proj": ParamDef((d, d), ("data", "model")),
        "dt_proj": ParamDef((d, 1), ("data", None)),
        "B_proj": ParamDef((d, n), ("data", None)),
        "C_proj": ParamDef((d, n), ("data", None)),
        "A_log": ParamDef((d, n), ("model", None), init="zeros"),
        "D_skip": ParamDef((d,), (None,), init="ones"),
        "conv_w": ParamDef((4, d), (None, "model"), init="zeros"),
        "out_proj": ParamDef((d, d), ("model", "data")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv, width 4.  x: (B, S, D); w: (4, D)."""
    pads = [jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :x.shape[1]] for k in range(4)]
    return sum(p * w[3 - k][None, None, :] for k, p in enumerate(pads))


def selective_scan(a, b, C, chunk: int = 256):
    """h_t = a_t * h_{t-1} + b_t; y_t = C_t . h_t, contracted PER CHUNK so
    the (B, S, D, N) state trajectory never materializes in HBM (live set is
    O(chunk), the property that keeps hymba's 32k prefill resident).
    a, b: (B, S, D, N); C: (B, S, N) -> (y (B, S, D), h_last)."""
    B, S, D, N = a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    def step(h, xs):
        ac, bc, cc = xs                              # (chunk, B, D, N)/(chunk, B, N)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=0)
        hs = aa * h[None] + bb                       # states for this chunk only
        y = jnp.einsum("cbdn,cbn->cbd", hs, cc)
        return hs[-1], y

    a_c = jnp.moveaxis(a.reshape(B, nc, chunk, D, N), (1, 2), (0, 1))
    b_c = jnp.moveaxis(b.reshape(B, nc, chunk, D, N), (1, 2), (0, 1))
    c_c = jnp.moveaxis(C.reshape(B, nc, chunk, N), (1, 2), (0, 1))
    h0 = jnp.zeros((B, D, N), a.dtype)
    h_last, ys = jax.lax.scan(jax.checkpoint(step), h0, (a_c, b_c, c_c))
    y = jnp.moveaxis(ys, (0, 1), (1, 2)).reshape(B, S, D)
    return y, h_last


def ssm_head(x, p, cfg, h0=None):
    """x: (B, S, D) -> (y, h_last).  h0: (B, D, N) decode state."""
    B, S, D = x.shape
    N = cfg.ssm_state
    xi = x @ p["in_proj"]
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"]) + xi)
    dt = jax.nn.softplus((xi @ p["dt_proj"]))                    # (B,S,1)
    Bm = xi @ p["B_proj"]                                        # (B,S,N)
    Cm = xi @ p["C_proj"]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (D,N) neg
    a = jnp.exp(dt[..., None] * A[None, None])                   # (B,S,D,N)
    b = (dt[..., None] * Bm[:, :, None, :]) * xi[..., None]      # (B,S,D,N)
    if h0 is None:
        y_state, h_last = selective_scan(a.astype(jnp.float32),
                                         b.astype(jnp.float32),
                                         Cm.astype(jnp.float32))
        y_state = y_state.astype(x.dtype)
    else:                                                        # decode (S small)
        def step(h, t):
            h = a[:, t].astype(jnp.float32) * h + b[:, t].astype(jnp.float32)
            return h, h
        h_last, hs = jax.lax.scan(step, h0, jnp.arange(S))
        hs = jnp.moveaxis(hs, 0, 1)
        y_state = jnp.einsum("bsdn,bsn->bsd", hs.astype(x.dtype), Cm)
    y = y_state + xi * p["D_skip"]
    return y @ p["out_proj"], h_last
