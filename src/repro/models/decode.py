"""Serving path: prefill + single-token decode with KV caches.

Cache layout is family-uniform so one lax.scan drives every layer stack:
  dense/moe : {'k','v'}  (B, S_max, Hkv, hd) per layer
  gemma3    : 5 RING buffers of length `window` + 1 full cache per superblock
  vlm       : 4 self caches per superblock; cross-attn memory stored ONCE
  encdec    : decoder self caches; encoder memory stored once
  hymba     : full attn cache + SSM state (h, conv tail) per layer
  rwkv6     : (token-shift tails, WKV state) per layer — O(1) in sequence!

Sliding-window ring buffers are what make long_500k decodable for gemma3 /
hymba: cache bytes scale with `window`, not with the 512k position.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_rope, attention_full, rms_norm, swiglu
from repro.models.transformer import (_mlp_sublayer, _moe_sublayer, _period,
                                      _n_superblocks, _sublayer_kind,
                                      logits_fn, forward)

CDT = jnp.bfloat16


# ------------------------------------------------------------ cache defs ---
def cache_struct(cfg, B: int, S_max: int):
    """ShapeDtypeStruct pytree of the decode cache (allocation-free)."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        init_cache(cfg, B, S_max, struct_only=True))


def init_cache(cfg, B: int, S_max: int, struct_only: bool = False):
    hd, Hkv, D = cfg.hd, cfg.n_kv_heads, cfg.d_model
    n_sb = _n_superblocks(cfg)
    w = cfg.sliding_window

    def z(shape, dtype=CDT):
        if struct_only:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    if cfg.family == "ssm":
        H = cfg.n_heads
        per = {"tm_tok": z((B, 1, D)), "wkv": z((B, H, hd, hd), jnp.float32),
               "cm_tok": z((B, 1, D))}
        return {"blocks": jax.tree.map(
            lambda s: (jax.ShapeDtypeStruct((n_sb,) + s.shape, s.dtype)
                       if struct_only else jnp.zeros((n_sb,) + s.shape, s.dtype)),
            per, is_leaf=lambda t: isinstance(t, (jax.ShapeDtypeStruct, jnp.ndarray)))}

    if cfg.family == "hybrid":
        per = {"k": z((B, S_max, Hkv, hd)), "v": z((B, S_max, Hkv, hd)),
               "ssm_h": z((B, D, cfg.ssm_state), jnp.float32),
               "conv": z((B, 4, D))}
    elif cfg.swa_period:
        nl = cfg.swa_period - 1
        per = {"k_loc": z((nl, B, w, Hkv, hd)), "v_loc": z((nl, B, w, Hkv, hd)),
               "k_glob": z((B, S_max, Hkv, hd)), "v_glob": z((B, S_max, Hkv, hd))}
    else:
        # unified layout: self-attn caches stacked over sublayers (n_self >= 1)
        n_self = _period(cfg) - (1 if cfg.cross_attn_period else 0)
        per = {"k": z((n_self, B, S_max, Hkv, hd)),
               "v": z((n_self, B, S_max, Hkv, hd))}

    def stack(s):
        if struct_only:
            return jax.ShapeDtypeStruct((n_sb,) + s.shape, s.dtype)
        return jnp.zeros((n_sb,) + s.shape, s.dtype)

    cache = {"blocks": jax.tree.map(
        stack, per, is_leaf=lambda t: isinstance(t, (jax.ShapeDtypeStruct, jnp.ndarray)))}
    if cfg.family == "vlm":
        cache["memory"] = z((B, cfg.n_vis_tokens, D))
    if cfg.is_encdec:
        cache["memory"] = z((B, S_max, D))
        cache["memory_len"] = z((), jnp.int32)
    return cache


# ------------------------------------------------------- kv projections ----
def _kv(x, p, cfg, positions):
    B, S, _ = x.shape
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _q(x, p, cfg, positions):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    return apply_rope(q, positions, cfg.rope_theta)


def _ring_fill(k_full, window):
    """Last `window` positions of k (B,S,n,hd) laid out ring-style."""
    B, S, n, hd = k_full.shape
    ring = jnp.zeros((B, window, n, hd), k_full.dtype)
    take = min(window, S)
    tail = k_full[:, S - take:]                       # (B,take,n,hd)
    pos = (jnp.arange(S - take, S)) % window
    return ring.at[:, pos].set(tail)


def _decode_attn(q, k_cache, v_cache, p, cfg, kv_len):
    """q: (B,1,H,hd) vs cache (B,L,n,hd) with kv_len valid entries."""
    o = attention_full(q, k_cache, v_cache, causal=False, kv_len=kv_len)
    B = q.shape[0]
    return o.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]


# ---------------------------------------------------------------- prefill --
def prefill(params, batch, cfg, par, S_max: int):
    """Run the full prompt; return (cache, last-token logits)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    chunked = S > 4096
    h, _ = forward(params, tokens, cfg, par, frames=batch.get("frames"),
                   vis=batch.get("vis"), chunked=chunked)
    # recompute per-layer caches from a second scan over blocks: cheap relative
    # to forward (projections only), and keeps forward() single-purpose.
    cache = init_cache(cfg, B, S_max)
    emb = params["embed"]
    x = par.constrain(emb[tokens].astype(jnp.dtype(cfg.dtype)), par.dp, None, None)
    positions = jnp.arange(S)

    if cfg.family == "vlm":
        cache["memory"] = batch["vis"].astype(CDT)
    if cfg.is_encdec:
        m = batch["frames"].astype(jnp.dtype(cfg.dtype))
        from repro.models.transformer import _attn_sublayer
        def enc_block(mh, pb):
            mh = _attn_sublayer(mh, pb["attn0"], cfg, par, positions=positions,
                                causal=False)
            mh = _mlp_sublayer(mh, pb["mlp0"], cfg, par)
            return mh, None
        m, _ = jax.lax.scan(enc_block, m, params["enc_blocks"])
        mem = rms_norm(m, params["enc_ln"], cfg.norm_eps)
        pad = S_max - S
        cache["memory"] = jnp.pad(mem, ((0, 0), (0, pad), (0, 0))).astype(CDT)
        cache["memory_len"] = jnp.asarray(S, jnp.int32)

    # one more pass through the blocks to collect (k, v) per layer — the
    # hidden state advances through the REAL sublayers so deep caches match
    from repro.models.transformer import _attn_sublayer
    enc_memory = cache.get("memory")
    if cfg.is_encdec:
        enc_memory_live = cache["memory"][:, :S]          # unpadded view
    else:
        enc_memory_live = enc_memory

    def collect(carry, pb):
        hh = carry
        entries = {}
        period = _period(cfg)
        for s in range(period):
            kind = _sublayer_kind(cfg, s)
            if kind == "cross":
                hh = _attn_sublayer(hh, pb[f"cross{s}"], cfg, par,
                                    positions=positions,
                                    memory=enc_memory_live.astype(hh.dtype))
            else:
                pa = pb[f"attn{s}"]
                xn = rms_norm(hh, pa["ln"], cfg.norm_eps)
                k, v = _kv(xn, pa, cfg, positions)
                if kind == "attn_local":
                    entries.setdefault("k_loc", []).append(
                        _ring_fill(k, cfg.sliding_window))
                    entries.setdefault("v_loc", []).append(
                        _ring_fill(v, cfg.sliding_window))
                    hh = _attn_sublayer(hh, pa, cfg, par, positions=positions,
                                        causal=True, window=cfg.sliding_window,
                                        chunked=chunked)
                else:
                    pad = ((0, 0), (0, S_max - S), (0, 0), (0, 0))
                    if cfg.swa_period:
                        entries["k_glob"] = jnp.pad(k, pad)
                        entries["v_glob"] = jnp.pad(v, pad)
                    else:
                        entries.setdefault("k", []).append(jnp.pad(k, pad))
                        entries.setdefault("v", []).append(jnp.pad(v, pad))
                    hh = _attn_sublayer(hh, pa, cfg, par, positions=positions,
                                        causal=True, chunked=chunked)
            if cfg.is_encdec:
                hh = _attn_sublayer(hh, pb[f"dec_cross{s}"], cfg, par,
                                    positions=positions,
                                    memory=enc_memory_live.astype(hh.dtype))
            if cfg.n_experts:
                hh, _ = _moe_sublayer(hh, pb[f"moe{s}"], cfg, par)
            else:
                hh = _mlp_sublayer(hh, pb[f"mlp{s}"], cfg, par)
        out = {}
        for key, val in entries.items():
            out[key] = jnp.stack(val, 0) if isinstance(val, list) else val
        return hh, out

    if cfg.family in ("ssm", "hybrid"):
        cache = _prefill_recurrent(params, x, cfg, par, cache, positions, S_max)
    else:
        _, per_layer = jax.lax.scan(collect, x, params["blocks"])
        cache["blocks"] = jax.tree.map(lambda a: a.astype(CDT)
                                       if a.dtype != jnp.float32 else a, per_layer)
    logits = logits_fn(params, h[:, -1:], cfg, par)
    return cache, logits


def _prefill_recurrent(params, x, cfg, par, cache, positions, S_max):
    S = x.shape[1]
    if cfg.family == "ssm":
        def block(carry, pb):
            hh, _ = rwkv_mod.rwkv_block(carry, pb["rwkv"], cfg)
            # emit shift/wkv states
            p = pb["rwkv"]
            xn = rms_norm(carry, p["ln1"], cfg.norm_eps)
            _, (tm_tok, wkv) = rwkv_mod.time_mix(xn, p, cfg)
            x2 = carry + (hh - carry) * 0  # placeholder; recompute below
            return hh, {"tm_tok": tm_tok.astype(CDT), "wkv": wkv,
                        "cm_tok": rms_norm(hh, p["ln2"], cfg.norm_eps)[:, -1:].astype(CDT)}
        _, per_layer = jax.lax.scan(block, x, params["blocks"])
        cache["blocks"] = per_layer
        return cache
    # hybrid: collect attn kv + ssm state
    def block(carry, xs):
        pb, glob = xs
        pa, ps = pb["attn0"], pb["ssm0"]
        xn = rms_norm(carry, pa["ln"], cfg.norm_eps)
        k, v = _kv(xn, pa, cfg, positions)
        ent = {"k": jnp.pad(k, ((0, 0), (0, S_max - S), (0, 0), (0, 0))).astype(CDT),
               "v": jnp.pad(v, ((0, 0), (0, S_max - S), (0, 0), (0, 0))).astype(CDT)}
        from repro.models.transformer import _hybrid_sublayer
        win = jnp.where(glob > 0, S + 1, cfg.sliding_window)
        hh = _hybrid_sublayer(carry, pa, ps, cfg, par, positions=positions,
                              window=win, chunked=False)
        # ssm terminal state
        xi = rms_norm(carry, pa["ln"], cfg.norm_eps)
        _, h_last = ssm_mod.ssm_head(xi, ps, cfg)
        ent["ssm_h"] = h_last
        ent["conv"] = jnp.pad((xi @ ps["in_proj"])[:, -4:],
                              ((0, 0), (max(0, 4 - S), 0), (0, 0))).astype(CDT)
        hh = _mlp_sublayer(hh, pb["mlp0"], cfg, par)
        return hh, ent
    n_sb = _n_superblocks(cfg)
    is_global = jnp.asarray([1 if i in cfg.global_layers else 0
                             for i in range(n_sb)], jnp.int32)
    _, per_layer = jax.lax.scan(block, x, (params["blocks"], is_global))
    cache["blocks"] = per_layer
    return cache


# ----------------------------------------------------------------- decode --
def decode_step(params, cache, tokens, pos, cfg, par):
    """One token for every sequence.  tokens: (B, 1); pos: scalar position.
    Returns (logits (B, 1, V-sharded), new cache)."""
    B = tokens.shape[0]
    emb = params["embed"]
    h = par.constrain(emb[tokens].astype(jnp.dtype(cfg.dtype)), par.dp, None, None)
    positions = jnp.full((1,), pos, jnp.int32)
    memory = cache.get("memory")
    n_sb = _n_superblocks(cfg)

    if cfg.family == "ssm":
        def block(carry, xs):
            pb, c = xs
            hh, new_c = rwkv_mod.rwkv_block(
                carry, pb["rwkv"], cfg,
                cache={"tm_tok": c["tm_tok"].astype(carry.dtype),
                       "wkv": c["wkv"], "cm_tok": c["cm_tok"].astype(carry.dtype)})
            new_c = {"tm_tok": new_c["tm_tok"].astype(CDT), "wkv": new_c["wkv"],
                     "cm_tok": new_c["cm_tok"].astype(CDT)}
            return hh, new_c
        h, new_blocks = jax.lax.scan(block, h, (params["blocks"], cache["blocks"]))
        new_cache = dict(cache, blocks=new_blocks)
    elif cfg.family == "hybrid":
        is_global = jnp.asarray([1 if i in cfg.global_layers else 0
                                 for i in range(n_sb)], jnp.int32)
        w = cfg.sliding_window

        def block(carry, xs):
            pb, c, glob = xs
            pa, ps = pb["attn0"], pb["ssm0"]
            xn = rms_norm(carry, pa["ln"], cfg.norm_eps)
            q = _q(xn, pa, cfg, positions)
            k, v = _kv(xn, pa, cfg, positions)
            kc = jax.lax.dynamic_update_slice_in_dim(c["k"], k.astype(CDT), pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(c["v"], v.astype(CDT), pos, axis=1)
            win = jnp.where(glob > 0, pos + 2, w)
            o = attention_full(q, kc.astype(q.dtype), vc.astype(q.dtype),
                               causal=False, window=win, q_offset=pos,
                               kv_len=pos + 1, par=par)
            o_attn = o.reshape(B, 1, -1) @ pa["wo"]
            # ssm single step
            xi = xn @ ps["in_proj"]
            conv = jnp.concatenate([c["conv"][:, 1:], xi.astype(CDT)], axis=1)
            xi = jax.nn.silu((conv.astype(xi.dtype) * ps["conv_w"][None]).sum(1, keepdims=True) + xi)
            dt = jax.nn.softplus(xi @ ps["dt_proj"])
            Bm = xi @ ps["B_proj"]
            Cm = xi @ ps["C_proj"]
            A = -jnp.exp(ps["A_log"].astype(jnp.float32))
            a = jnp.exp(dt[..., None] * A[None, None])[:, 0]
            bterm = ((dt[..., None] * Bm[:, :, None, :]) * xi[..., None])[:, 0]
            h_new = a * c["ssm_h"] + bterm.astype(jnp.float32)
            y_ssm = jnp.einsum("bdn,bn->bd", h_new.astype(xi.dtype), Cm[:, 0])
            y_ssm = (y_ssm + xi[:, 0] * ps["D_skip"])[:, None] @ ps["out_proj"]
            hh = carry + 0.5 * (o_attn + y_ssm)
            hh = _mlp_sublayer(hh, pb["mlp0"], cfg, par)
            return hh, {"k": kc, "v": vc, "ssm_h": h_new, "conv": conv}
        h, new_blocks = jax.lax.scan(block, h,
                                     (params["blocks"], cache["blocks"], is_global))
        new_cache = dict(cache, blocks=new_blocks)
    else:
        w = cfg.sliding_window

        from repro.models.transformer import _attn_sublayer
        mem_len = cache.get("memory_len")

        def block(carry, xs):
            pb, c = xs
            hh = carry
            new_c = dict(c)
            si = 0   # self-attn sublayer counter (stacked cache index)
            li = 0   # local (ring) sublayer counter
            for s in range(_period(cfg)):
                kind = _sublayer_kind(cfg, s)
                if kind == "cross":
                    hh = _attn_sublayer(hh, pb[f"cross{s}"], cfg, par,
                                        positions=positions,
                                        memory=memory.astype(hh.dtype))
                else:
                    pa = pb[f"attn{s}"]
                    xn = rms_norm(hh, pa["ln"], cfg.norm_eps)
                    q = _q(xn, pa, cfg, positions)
                    k, v = _kv(xn, pa, cfg, positions)
                    if kind == "attn_local":
                        slot = jax.lax.rem(pos, w)
                        kc = jax.lax.dynamic_update_slice(
                            c["k_loc"], k[None].astype(CDT), (li, 0, slot, 0, 0))
                        vc = jax.lax.dynamic_update_slice(
                            c["v_loc"], v[None].astype(CDT), (li, 0, slot, 0, 0))
                        new_c["k_loc"], new_c["v_loc"] = kc, vc
                        kv_len = jnp.minimum(pos + 1, w)
                        o = attention_full(q, kc[li].astype(q.dtype),
                                           vc[li].astype(q.dtype),
                                           causal=False, kv_len=kv_len, par=par)
                        hh = hh + o.reshape(B, 1, -1) @ pa["wo"]
                        li += 1
                    elif cfg.swa_period:        # the one global layer
                        kc = jax.lax.dynamic_update_slice_in_dim(
                            c["k_glob"], k.astype(CDT), pos, axis=1)
                        vc = jax.lax.dynamic_update_slice_in_dim(
                            c["v_glob"], v.astype(CDT), pos, axis=1)
                        new_c["k_glob"], new_c["v_glob"] = kc, vc
                        o = attention_full(q, kc.astype(q.dtype),
                                           vc.astype(q.dtype),
                                           causal=False, kv_len=pos + 1, par=par)
                        hh = hh + o.reshape(B, 1, -1) @ pa["wo"]
                    else:                       # unified stacked self cache
                        kc = jax.lax.dynamic_update_slice(
                            c["k"], k[None].astype(CDT), (si, 0, pos, 0, 0))
                        vc = jax.lax.dynamic_update_slice(
                            c["v"], v[None].astype(CDT), (si, 0, pos, 0, 0))
                        new_c["k"], new_c["v"] = kc, vc
                        o = attention_full(q, kc[si].astype(q.dtype),
                                           vc[si].astype(q.dtype),
                                           causal=False, kv_len=pos + 1, par=par)
                        hh = hh + o.reshape(B, 1, -1) @ pa["wo"]
                        si += 1
                if cfg.is_encdec:
                    hh = _attn_sublayer(hh, pb[f"dec_cross{s}"], cfg, par,
                                        positions=positions,
                                        memory=memory.astype(hh.dtype),
                                        kv_len=mem_len)
                if cfg.n_experts:
                    hh, _ = _moe_sublayer(hh, pb[f"moe{s}"], cfg, par)
                else:
                    hh = _mlp_sublayer(hh, pb[f"mlp{s}"], cfg, par)
            return hh, new_c

        h, new_blocks = jax.lax.scan(block, h, (params["blocks"], cache["blocks"]))
        new_cache = dict(cache, blocks=new_blocks)

    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    return logits_fn(params, h, cfg, par), new_cache
