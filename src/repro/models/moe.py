"""Expert-parallel MoE with explicit all-to-all dispatch.

This is where the paper's HSDX idea lands in the LM framework: expert
dispatch is a sparse data exchange.  Experts are sharded over the `model`
axis; tokens are routed with top-k gating and fixed per-group capacity
(ORB-style balance: capacity is the histogram-splitter analogue), then
exchanged with `lax.all_to_all` inside a shard_map manual over
(data, model[, pod]).  With `hierarchical=True` and a pod axis, the a2a runs
in two stages (intra-pod, inter-pod) via core.collectives.two_stage_all_to_all
— the HSDX relay — keeping every transfer on direct links.

A collective-free dense path (`_moe_dense`) with identical math serves single-
device smoke tests and as the oracle for the shard_map path.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.collectives import two_stage_all_to_all
from repro.models.params import ParamDef


def moe_defs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, e), (None, None), dtype="float32"),
        "w_gate": ParamDef((e, d, f), ("model", "data", None)),
        "w_up": ParamDef((e, d, f), ("model", "data", None)),
        "w_down": ParamDef((e, f, d), ("model", None, "data")),
    }


def _route(x2d, router_w, n_experts, top_k, capacity):
    """Common routing math.  x2d: (T, D) -> dispatch metadata."""
    logits = (x2d.astype(jnp.float32) @ router_w)               # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)         # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # position of each (token, k) slot within its expert's capacity
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)  # (T,k,E)
    flat = onehot.reshape(-1, n_experts)                        # (T*k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                       # pos before me
    pos = (pos * flat).sum(-1).reshape(-1, top_k)               # (T, k)
    keep = pos < capacity
    # aux losses: load-balance (switch) + router z-loss
    frac = flat.reshape(-1, top_k, n_experts).sum(1).mean(0)    # tokens/expert
    imp = probs.mean(0)
    aux = n_experts * jnp.sum(frac * imp) + 1e-3 * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gate_vals, expert_idx, pos, keep, aux


def _dispatch(x2d, expert_idx, pos, keep, n_experts, capacity):
    """Scatter tokens into the (E, C, D) send buffer."""
    T, D = x2d.shape
    k = expert_idx.shape[1]
    slot = (expert_idx * capacity + pos).reshape(-1)            # (T*k,)
    slot = jnp.where(keep.reshape(-1), slot, n_experts * capacity)  # dropped
    buf = jnp.zeros((n_experts * capacity + 1, D), x2d.dtype)
    buf = buf.at[slot].add(jnp.repeat(x2d, k, axis=0))
    return buf[:-1].reshape(n_experts, capacity, D)


def _combine(y_buf, gate_vals, expert_idx, pos, keep):
    """Gather expert outputs back to tokens, weighted by gates."""
    E, C, D = y_buf.shape
    T, k = expert_idx.shape
    slot = (expert_idx * C + pos).reshape(-1)
    rows = y_buf.reshape(E * C, D)[jnp.where(keep.reshape(-1), slot, 0)]
    rows = rows * (keep.reshape(-1, 1) * gate_vals.reshape(-1, 1)).astype(rows.dtype)
    return rows.reshape(T, k, D).sum(axis=1)


def _expert_ffn(xb, w_gate, w_up, w_down):
    """xb: (E_loc, C', D); weights (E_loc, D, F)/(E_loc, F, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xb, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _capacity(tokens: int, cfg) -> int:
    c = math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _moe_dense(x, p, cfg):
    """Single-shard reference (also the smoke-test path)."""
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    C = _capacity(x2d.shape[0], cfg)
    gate, eidx, pos, keep, aux = _route(x2d, p["router"], cfg.n_experts,
                                        cfg.top_k, C)
    buf = _dispatch(x2d, eidx, pos, keep, cfg.n_experts, C)
    y_buf = _expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"])
    y = _combine(y_buf, gate, eidx, pos, keep)
    return y.reshape(B, S, D), aux


def moe_ffn(x, p, cfg, par):
    """x: (B, S, D) -> (y, aux_loss)."""
    if par.mesh is None or par.model_axis is None or par.tp_size() == 1:
        return _moe_dense(x, p, cfg)
    return _moe_shard_map(x, p, cfg, par)


def _moe_shard_map(x, p, cfg, par):
    mesh = par.mesh
    n_model = mesh.shape[par.model_axis]
    assert cfg.n_experts % n_model == 0, (cfg.n_experts, n_model)
    dp = par.data_axes
    model = par.model_axis
    manual = set(dp) | {model}

    def body(xl, router_w, w_gate, w_up, w_down):
        # xl: (B_loc, S, D) local tokens — REPLICATED over the model axis;
        # experts local on axis 0
        B_loc, S, D = xl.shape
        x2d = xl.reshape(-1, D)
        T_full = x2d.shape[0]
        # §Perf hillclimb: without sequence sharding every model shard routes
        # the SAME tokens, so dispatch compute and a2a bytes are replicated
        # n_model times.  Slicing tokens over the model axis first removes
        # the redundancy (Megatron-style sequence parallelism applied to MoE).
        seq_shard = par.moe_seq_shard and T_full % n_model == 0
        if seq_shard:
            me = jax.lax.axis_index(model)
            Tl = T_full // n_model
            x2d = jax.lax.dynamic_slice_in_dim(x2d, me * Tl, Tl, axis=0)
        C = _capacity(x2d.shape[0], cfg)
        gate, eidx, pos, keep, aux = _route(x2d, router_w, cfg.n_experts,
                                            cfg.top_k, C)
        buf = _dispatch(x2d, eidx, pos, keep, cfg.n_experts, C)   # (E, C, D)
        # FSDP gather of expert weights over the data axes (ZeRO-3)
        for ax in dp:
            w_gate = jax.lax.all_gather(w_gate, ax, axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up, ax, axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, ax, axis=2, tiled=True)
        if par.hierarchical and par.pod_axis and par.pod_axis in dp:
            # HSDX two-stage dispatch is available when EP spans pods; with
            # EP inside one pod, token exchange stays on intra-pod links and
            # only weight-FSDP gathers cross pods (already hierarchical).
            pass
        # a2a with split==concat axis (clean transpose rule); destination-
        # major reshape keeps expert rows contiguous per rank
        E_loc = cfg.n_experts // n_model
        buf4 = buf.reshape(n_model, E_loc * C, D)
        recv = jax.lax.all_to_all(buf4, model, split_axis=0, concat_axis=0)
        recv = recv.reshape(n_model, E_loc, C, D).transpose(1, 0, 2, 3) \
                   .reshape(E_loc, n_model * C, D)
        y = _expert_ffn(recv, w_gate, w_up, w_down)               # (E_loc, nC, D)
        y4 = y.reshape(E_loc, n_model, C, D).transpose(1, 0, 2, 3) \
              .reshape(n_model, E_loc * C, D)
        back = jax.lax.all_to_all(y4, model, split_axis=0, concat_axis=0)
        back = back.reshape(cfg.n_experts, C, D)
        out = _combine(back, gate, eidx, pos, keep)
        if seq_shard:
            # reconstruct the full token set (transpose: reduce-scatter)
            out = jax.lax.all_gather(out, model, axis=0, tiled=True)
            aux = jax.lax.pmean(aux, model)
        # aux identical across model (replicated routing); average over data
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(B_loc, S, D), aux

    # expert weights enter UN-gathered on their FSDP (data) dim — the body
    # all-gathers them manually (ZeRO-3); specs must match the true layout
    fsdp = dp if dp else None
    in_specs = (P(dp, None, None), P(None, None),
                P(model, fsdp, None), P(model, fsdp, None),
                P(model, None, fsdp))
    out_specs = (P(dp, None, None), P())
    if hasattr(jax, "shard_map"):            # jax >= 0.6 top-level API
        fn = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, axis_names=manual,
                           check_vma=False)
    else:                                    # older jax: experimental API
        from jax.experimental.shard_map import shard_map as _shard_map
        auto = frozenset(mesh.axis_names) - set(manual)
        kw = dict(check_rep=False)
        if auto:
            kw["auto"] = auto
        fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, **kw)
    y, aux = fn(x, p["router"].astype(jnp.float32), p["w_gate"], p["w_up"],
                p["w_down"])
    return y, aux
