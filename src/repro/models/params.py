"""Parameter definition system: one structure, three views.

`ParamDef` trees describe every weight (shape, dtype, init scale, PartitionSpec).
From the same tree we derive:
  - `init_params`   : materialized arrays (real runs, smoke tests)
  - `param_structs` : ShapeDtypeStruct pytree (dry-run lowering, no allocation)
  - `param_shardings`: NamedSharding pytree (in_shardings for jit)
FSDP convention: every >=2D weight is sharded over ('data', ...) on one dim
and 'model' on another where the math demands it (TP/EP).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["ParamDef", "init_params", "param_structs", "param_shardings",
           "stack_defs"]


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    spec: tuple = ()            # PartitionSpec entries (axis names / None)
    init: str = "normal"        # normal | zeros | ones
    scale: float | None = None  # None -> 1/sqrt(fan_in)
    dtype: str = "bfloat16"


def stack_defs(defs, n: int):
    """Prepend a stacking dim (scan-over-layers) to every def in a tree."""
    def one(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + tuple(d.shape), (None,) + tuple(d.spec),
                        d.init, d.scale, d.dtype)
    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = d.scale if d.scale is not None else 1.0 / np.sqrt(fan_in)
            out.append((jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def param_structs(defs):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
                        defs, is_leaf=_is_def)


def param_shardings(defs, mesh, fsdp_pod: bool = False):
    """fsdp_pod=True extends the FSDP shard from 'data' to ('pod','data') —
    fully flat ZeRO-3 across pods (the baseline the hierarchical layout
    beats on inter-pod links; see EXPERIMENTS.md §Perf)."""
    def one(d: ParamDef):
        if mesh is None:
            return None
        spec = tuple(("pod", "data") if (fsdp_pod and e == "data"
                                         and "pod" in mesh.axis_names) else e
                     for e in d.spec)
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, defs, is_leaf=_is_def)
