"""Decoder/encoder-decoder transformer stack covering all assigned families.

One scan-over-superblocks drives every architecture: a *superblock* is the
repeating layer pattern (dense: 1 layer; gemma3: 5 local + 1 global; vlm:
4 self + 1 cross; moe: attn + expert FFN; hymba: parallel attn+SSM; rwkv6:
time-mix + channel-mix).  Params are stacked over superblocks so the HLO is
one rolled loop — essential for 512-way GSPMD compile times.

KV caches for sliding-window layers are RING BUFFERS of length `window`
(a 512k-context gemma3 decode keeps 40/48 layers at window size — the reason
the long_500k cell fits).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_rope, attention_chunked, attention_full,
                                 decode_attention, rms_norm, swiglu)
from repro.models.params import ParamDef, stack_defs

MAX_DECODE_LEN = {"decode_32k": 32768, "long_500k": 524288}


# ============================================================ param defs ====
def attn_defs(cfg):
    d, hd, H, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    defs = {
        "ln": ParamDef((d,), (None,), init="ones"),
        "wq": ParamDef((d, H * hd), ("data", "model")),
        "wk": ParamDef((d, Hkv * hd), ("data", "model")),
        "wv": ParamDef((d, Hkv * hd), ("data", "model")),
        "wo": ParamDef((H * hd, d), ("model", "data")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="ones")
        defs["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return defs


def mlp_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln": ParamDef((d,), (None,), init="ones"),
        "w_gate": ParamDef((d, f), ("data", "model")),
        "w_up": ParamDef((d, f), ("data", "model")),
        "w_down": ParamDef((f, d), ("model", "data")),
    }


def superblock_defs(cfg, decoder=True):
    """Param defs for ONE superblock of the given family."""
    fam = cfg.family
    if fam == "ssm":
        return {"rwkv": rwkv_mod.rwkv_defs(cfg)}
    blocks = {}
    period = _period(cfg)
    for s in range(period):
        kind = _sublayer_kind(cfg, s, decoder)
        if kind in ("attn", "attn_local", "attn_global", "attn_bidir"):
            blocks[f"attn{s}"] = attn_defs(cfg)
        elif kind == "cross":
            blocks[f"cross{s}"] = attn_defs(cfg)
        if fam == "hybrid":
            blocks[f"ssm{s}"] = ssm_mod.ssm_defs(cfg)
        if cfg.n_experts and decoder:
            blocks[f"moe{s}"] = dict(moe_mod.moe_defs(cfg),
                                     ln=ParamDef((cfg.d_model,), (None,), init="ones"))
        else:
            blocks[f"mlp{s}"] = mlp_defs(cfg)
        if fam == "encdec" and decoder:
            blocks[f"dec_cross{s}"] = attn_defs(cfg)
    return blocks


def _period(cfg) -> int:
    if cfg.swa_period:
        return cfg.swa_period
    if cfg.cross_attn_period:
        return cfg.cross_attn_period
    return 1


def _n_superblocks(cfg, decoder=True) -> int:
    n = cfg.n_layers if decoder else cfg.n_enc_layers
    period = _period(cfg) if decoder else 1
    assert n % period == 0, (n, period)
    return n // period


def _sublayer_kind(cfg, s, decoder=True) -> str:
    if not decoder:
        return "attn_bidir"
    if cfg.swa_period:
        return "attn_local" if s < cfg.swa_period - 1 else "attn_global"
    if cfg.cross_attn_period:
        return "cross" if s == cfg.cross_attn_period - 1 else "attn"
    return "attn"


def padded_vocab(cfg) -> int:
    """Embedding tables padded to a 256 multiple so the vocab dim shards
    evenly over any mesh axis (labels never index the padding)."""
    return -(-cfg.vocab // 256) * 256


def model_defs(cfg):
    d = cfg.d_model
    vp = padded_vocab(cfg)
    defs = {
        "embed": ParamDef((vp, d), ("model", "data"), scale=0.02),
        "final_ln": ParamDef((d,), (None,), init="ones"),
        "blocks": stack_defs(superblock_defs(cfg, decoder=True),
                             _n_superblocks(cfg)),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, vp), ("data", "model"), scale=0.02)
    if cfg.is_encdec:
        defs["enc_blocks"] = stack_defs(superblock_defs(cfg, decoder=False),
                                        cfg.n_enc_layers)
        defs["enc_ln"] = ParamDef((d,), (None,), init="ones")
    return defs


# =========================================================== sub-layers =====
def _attn_sublayer(h, p, cfg, par, *, positions, causal=True, window=None,
                   memory=None, chunked=False, kv_len=None):
    """Pre-norm attention (self or cross) with residual."""
    B, S, D = h.shape
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    src = x if memory is None else memory
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], Hkv, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if memory is None:                       # RoPE only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if memory is not None:
        o = attention_full(q, k, v, causal=False, kv_len=kv_len, par=par)
    elif chunked:
        o = attention_chunked(q, k, v, causal=causal, window=window,
                              q_chunk=par.q_chunk, kv_chunk=par.kv_chunk,
                              par=par)
    else:
        o = attention_full(q, k, v, causal=causal, window=window, par=par)
    o = o.reshape(B, S, H * hd) @ p["wo"]
    return h + par.constrain(o, par.dp, None, None)


def _mlp_sublayer(h, p, cfg, par):
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    return h + par.constrain(swiglu(x, p["w_gate"], p["w_up"], p["w_down"]),
                             par.dp, None, None)


def _moe_sublayer(h, p, cfg, par):
    x = rms_norm(h, p["ln"], cfg.norm_eps)
    y, aux = moe_mod.moe_ffn(x, p, cfg, par)
    return h + par.constrain(y, par.dp, None, None), aux


def _hybrid_sublayer(h, p_attn, p_ssm, cfg, par, *, positions, window, chunked):
    """hymba: attention and SSM heads in parallel, outputs fused (mean)."""
    x = rms_norm(h, p_attn["ln"], cfg.norm_eps)
    B, S, D = x.shape
    hd, H, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = apply_rope((x @ p_attn["wq"]).reshape(B, S, H, hd), positions, cfg.rope_theta)
    k = apply_rope((x @ p_attn["wk"]).reshape(B, S, Hkv, hd), positions, cfg.rope_theta)
    v = (x @ p_attn["wv"]).reshape(B, S, Hkv, hd)
    # window may be traced (per-layer global flag) -> masked full attention
    o_attn = attention_full(q, k, v, causal=True, window=window, par=par) \
        if not chunked else attention_chunked(q, k, v, causal=True, window=None,
                                              q_chunk=par.q_chunk,
                                              kv_chunk=par.kv_chunk, par=par)
    o_attn = o_attn.reshape(B, S, H * hd) @ p_attn["wo"]
    o_ssm, _ = ssm_mod.ssm_head(x, p_ssm, cfg)
    return h + par.constrain(0.5 * (o_attn + o_ssm), par.dp, None, None)


# ============================================================= forward ======
def forward(params, tokens, cfg, par, *, frames=None, vis=None, chunked=False):
    """Full-sequence forward -> final hidden states (B, S, D)."""
    B, S = tokens.shape
    emb = params["embed"]
    h = emb[tokens].astype(jnp.dtype(cfg.dtype))  # gather, sharded over model? keep auto
    h = par.constrain(h, par.dp, None, None)
    positions = jnp.arange(S)

    memory = None
    if cfg.is_encdec:
        assert frames is not None
        m = par.constrain(frames.astype(h.dtype), par.dp, None, None)
        enc_positions = jnp.arange(frames.shape[1])

        def enc_block(mh, pb):
            mh = _attn_sublayer(mh, pb["attn0"], cfg, par, positions=enc_positions,
                                causal=False, chunked=chunked)
            mh = _mlp_sublayer(mh, pb["mlp0"], cfg, par)
            return mh, None
        fn = jax.checkpoint(enc_block) if par.remat else enc_block
        m, _ = jax.lax.scan(lambda c, pb: fn(c, pb), m, params["enc_blocks"])
        memory = rms_norm(m, params["enc_ln"], cfg.norm_eps)
    if cfg.family == "vlm":
        assert vis is not None
        memory = par.constrain(vis.astype(h.dtype), par.dp, None, None)

    n_sb = _n_superblocks(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        def block(carry, pb):
            hh, _ = rwkv_mod.rwkv_block(carry, pb["rwkv"], cfg)
            return hh, jnp.zeros(())
        fn = jax.checkpoint(block) if par.remat else block
        h, _ = jax.lax.scan(fn, h, params["blocks"])
    elif cfg.family == "hybrid":
        is_global = jnp.asarray([1 if i in cfg.global_layers else 0
                                 for i in range(n_sb)], jnp.int32)

        def block(carry, xs):
            pb, glob = xs
            win = jnp.where(glob > 0, S + 1, cfg.sliding_window)
            hh = _hybrid_sublayer(carry, pb["attn0"], pb["ssm0"], cfg, par,
                                  positions=positions, window=win, chunked=False)
            hh = _mlp_sublayer(hh, pb["mlp0"], cfg, par)
            return hh, jnp.zeros(())
        fn = jax.checkpoint(block) if par.remat else block
        h, _ = jax.lax.scan(fn, h, (params["blocks"], is_global))
    else:
        def block(carry, pb):
            hh, aux = carry
            for s in range(_period(cfg)):
                kind = _sublayer_kind(cfg, s)
                if kind == "cross":
                    hh = _attn_sublayer(hh, pb[f"cross{s}"], cfg, par,
                                        positions=positions, memory=memory)
                elif kind == "attn_local":
                    hh = _attn_sublayer(hh, pb[f"attn{s}"], cfg, par,
                                        positions=positions, causal=True,
                                        window=cfg.sliding_window, chunked=chunked)
                else:
                    hh = _attn_sublayer(hh, pb[f"attn{s}"], cfg, par,
                                        positions=positions, causal=True,
                                        chunked=chunked)
                if cfg.is_encdec:
                    hh = _attn_sublayer(hh, pb[f"dec_cross{s}"], cfg, par,
                                        positions=positions, memory=memory)
                if cfg.n_experts:
                    hh, aux_l = _moe_sublayer(hh, pb[f"moe{s}"], cfg, par)
                    aux = aux + aux_l
                else:
                    hh = _mlp_sublayer(hh, pb[f"mlp{s}"], cfg, par)
            return (hh, aux), None
        fn = jax.checkpoint(block) if par.remat else block
        (h, aux_total), _ = jax.lax.scan(fn, (h, aux_total), params["blocks"])

    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    return h, aux_total


def logits_fn(params, h, cfg, par):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return par.constrain(logits, par.dp, None, par.tp)


def chunked_xent(params, h, labels, cfg, par, chunk: int = 512):
    """Vocab-sharded, sequence-chunked softmax cross-entropy (the full
    (B, S, V) logits tensor never materializes)."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

    def step(acc, i):
        hs = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = par.constrain((hs @ w.astype(hs.dtype)).astype(jnp.float32),
                               par.dp, None, par.tp)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    # remat each vocab chunk: the (B, chunk, V) logits block is recomputed in
    # backward instead of living across the whole loss scan
    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            jnp.arange(nc))
    return total / (B * S)


def loss_fn(params, batch, cfg, par, chunked=False):
    h, aux = forward(params, batch["tokens"], cfg, par,
                     frames=batch.get("frames"), vis=batch.get("vis"),
                     chunked=chunked)
    ce = chunked_xent(params, h, batch["labels"], cfg, par)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}
