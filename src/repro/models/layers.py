"""Shared model layers: RMSNorm, RoPE, SwiGLU, attention (full + chunked
online-softmax "jnp-flash"), KV-cache decode attention.

The chunked path is the TPU-native structure (query tile resident, KV
streaming) whose fused twin is kernels/attention.py; on CPU/dry-run the jnp
version is lowered so the roofline sees real FLOPs/bytes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x, gamma, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def rope_freqs(hd: int, theta: float, dtype=jnp.float32):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=dtype) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _gqa_scores(q, k):
    """q: (B, Sq, Hkv, G, hd); k: (B, Sk, Hkv, hd) -> (B, Hkv, G, Sq, Sk)."""
    return jnp.einsum("bsngd,btnd->bngst", q, k).astype(jnp.float32)


def attention_full(q, k, v, *, causal=True, window=None, q_offset=0,
                   kv_len=None, par=None):
    """One-shot masked attention.  q: (B, Sq, H, hd); k/v: (B, Sk, Hkv, hd).
    `window` may be a traced scalar (hymba's mixed global/local layers).
    `kv_len` masks padded cache tails (decode).  Returns (B, Sq, H, hd).

    With `par`, scores are constrained to shard the KV-sequence dim over the
    model axis: head counts rarely divide the TP degree, and without the
    constraint GSPMD parks the leftover factor on the *contraction* (head_dim)
    — turning every score block into a partial sum that must be all-reduced
    (hundreds of GB/step at 32k context; see EXPERIMENTS.md §Perf)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd) * (hd ** -0.5)
    seq_ok = par is not None and par.tp_size() > 1 and Sk % par.tp_size() == 0
    dp = par.dp if (par is not None and par.dp and B % par.dp_size() == 0) else None
    if seq_ok:
        qg = par.constrain(qg, dp, None, None, None, None)
        k = par.constrain(k, dp, par.tp, None, None)
        v = par.constrain(v, dp, par.tp, None, None)
    s = _gqa_scores(qg, k)                                     # (B,n,G,Sq,Sk)
    if seq_ok:
        s = par.constrain(s, dp, None, None, None, par.tp)
    qi = q_offset + jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    if kv_len is not None:
        mask &= ki < kv_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bngst,btnd->bsngd", p, v)
    return o.reshape(B, Sq, H, hd)


def attention_chunked(q, k, v, *, causal=True, window=None,
                      q_chunk=256, kv_chunk=1024, par=None):
    """Online-softmax attention: scan over query tiles, inner scan over KV
    tiles with running (max, sum) — O(q_chunk * kv_chunk) live memory.

    For sliding-window layers (static `window`) only ceil((window+q_chunk)/
    kv_chunk) KV tiles are touched per query tile (dynamic_slice), so the
    FLOPs scale with the window, not the sequence — the property that makes
    gemma3-style 5:1 patterns profitable at 32k-512k.
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, Sk, q_chunk, kv_chunk)
    nq = Sq // q_chunk

    static_window = isinstance(window, int)
    if static_window:
        # KV span touched per query tile, rounded to tile size
        span = window + q_chunk
        n_kv_tiles = min((span + kv_chunk - 1) // kv_chunk + 1, Sk // kv_chunk)
    else:
        n_kv_tiles = Sk // kv_chunk

    qg = (q * (hd ** -0.5)).reshape(B, Sq, Hkv, G, hd)
    qg = jnp.moveaxis(qg.reshape(B, nq, q_chunk, Hkv, G, hd), 1, 0)  # (nq,B,qc,n,G,hd)

    # shard the QUERY-TILE dim over the model axis: every score tile
    # (B,n,G,qc/16,kc) is then fully local — no partial-contraction
    # all-reduce fires inside the double scan (the §Perf fix)
    tile_ok = par is not None and par.tp_size() > 1 and q_chunk % par.tp_size() == 0
    dp_e = par.dp if (par is not None and par.dp and B % par.dp_size() == 0) else None

    def q_tile(_, qt_idx):
        qt, qi0 = qt_idx                                   # (B,qc,n,G,hd), scalar
        if tile_ok:
            qt = par.constrain(qt, dp_e, par.tp, None, None, None)
        if static_window:
            lo = jnp.maximum(qi0 + q_chunk - (n_kv_tiles * kv_chunk), 0)
            lo = (lo // kv_chunk) * kv_chunk
        else:
            lo = 0

        def kv_tile(carry, i):
            acc, m_i, l_i = carry
            k0 = lo + i * kv_chunk
            kt = jax.lax.dynamic_slice_in_dim(k, k0, kv_chunk, axis=1)
            vt = jax.lax.dynamic_slice_in_dim(v, k0, kv_chunk, axis=1)
            if tile_ok:
                kt = par.constrain(kt, dp_e, None, None, None)
                vt = par.constrain(vt, dp_e, None, None, None)
            s = _gqa_scores(qt, kt)                        # (B,n,G,qc,kc)
            if tile_ok:
                s = par.constrain(s, dp_e, None, None, par.tp, None)
            qi = qi0 + jnp.arange(q_chunk)[:, None]
            ki = k0 + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= ki <= qi
            if window is not None:
                mask &= ki > qi - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_i - m_new)
            l_new = alpha * l_i + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bngst,btnd->bngsd", p.astype(vt.dtype), vt).astype(jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, Hkv, G, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        (acc, m_i, l_i), _ = jax.lax.scan(kv_tile, (acc0, m0, l0),
                                          jnp.arange(n_kv_tiles))
        o = acc / jnp.maximum(l_i, 1e-30)[..., None]       # (B,n,G,qc,hd)
        return None, o.astype(q.dtype)

    # nested remat: without it the q/kv chunk scans stash per-chunk softmax
    # residuals for backward (O(S^2 / kv_chunk) live bytes) — with it the
    # backward recomputes one tile at a time (flash-attention memory law)
    _, tiles = jax.lax.scan(jax.checkpoint(q_tile), None,
                            (qg, jnp.arange(nq) * q_chunk))
    # tiles: (nq, B, n, G, qc, hd) -> (B, Sq, H, hd)
    o = jnp.moveaxis(tiles, 0, 3)                          # (B,n,G,nq,qc,hd)
    o = o.reshape(B, Hkv, G, Sq, hd)
    o = jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd)
    return o


def decode_attention(q, k_cache, v_cache, pos, *, window=None):
    """Single-token attention against a cache.  q: (B, 1, H, hd);
    k/v_cache: (B, S_max, Hkv, hd); pos: current position (scalar)."""
    return attention_full(q, k_cache, v_cache, causal=True, window=window,
                          q_offset=pos, kv_len=pos + 1)
