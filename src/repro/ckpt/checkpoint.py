"""Sharded .npz checkpointing with a manifest + elastic resharding.

Layout:  <dir>/step_<N>/shard_<k>.npz + manifest.json
Each host saves its own shard (here: one process = shard 0, but the format
is multi-host: the manifest records the global pytree structure and each
leaf's full shape so a restart on a *different* mesh re-shards on load —
the elastic-scaling path tested in tests/test_ckpt.py).  Writes are
atomic (tmp + rename) so a crash mid-save never corrupts the latest step.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:   # npz can't round-trip bf16; upcast
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None,
                    keep: int = 3):
    """Atomic save of a pytree (+ JSON-serializable extras e.g. data cursor)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        arrays = _flatten_with_paths(tree)
        np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in arrays.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`; optional resharding onto a
    (possibly different) mesh via `shardings` (elastic scaling)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = dict(np.load(os.path.join(d, "shard_0.npz")))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    out = []
    sh_flat = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(flat))
    for (path, leaf), sh in zip(flat, sh_flat):
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = arrays[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if sh is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh))
        else:
            out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
