"""Resilience tier: fault injection, retry/backoff, degradation ladder.

Two halves, both zero-overhead when idle (the obs tier's no-op-singleton
discipline, tracemalloc-pinned):

  `faults`   — named injection sites at the stack's real failure seams,
               armed via `inject_faults(...)` or `REPRO_FAULTS=`; disarmed,
               each seam costs one module-global load.
  `fallback` — the `streaming -> gathered -> xla_slab -> per_phase ->
               reference` ladder (plus `dist -> single-device`), bounded
               retry with deterministic backoff, and the process ledgers
               `analysis.check_counters` reconciles against fired faults.

Enable on a session with `FMMSession(..., resilience=True)` (or
`REPRO_RESILIENCE=1`); inspect via `session.report()["resilience"]`.
"""
from repro.resilience.faults import (InjectedFault, InjectedResourceExhausted,
                                     SITES, fire, inject_faults)
from repro.resilience.fallback import (LADDER, ExchangeVerificationError,
                                       ResilienceError, ResilienceState,
                                       RetryPolicy, call_with_retry,
                                       default_resilience_enabled)

__all__ = ["SITES", "LADDER", "InjectedFault", "InjectedResourceExhausted",
           "ResilienceError", "ExchangeVerificationError", "ResilienceState",
           "RetryPolicy", "inject_faults", "fire", "call_with_retry",
           "default_resilience_enabled"]
