"""Deterministic, seedable fault injection at the stack's real failure seams.

The degradation ladder (repro.resilience.fallback) is only credible if the
failures it guards against can be produced ON PURPOSE, exactly, in CI.  This
module registers one named injection site at each seam where the optimized
stack actually touches something that can fail in production — disk, XLA,
the Pallas launch path, host->device transfer, the collective-program
builder — and arms them with per-site count/probability budgets so a chaos
test can fire a site exactly once and assert the precise consequence.

Sites (`SITES`) and where they fire:

  p2p.cache.read    kernels.p2p._load_persisted  (autotune disk cache read)
  p2p.cache.write   kernels.p2p._save_persisted  (autotune disk cache write)
  exe_cache.compile engine.exe_cache.ExecutableCache.get_or_compile
                    (inside the retried compile closure — the XLA AOT seam)
  p2p.stream.tables engine.schedules.build_p2p_stream_tables
  kernels.p2p.launch engine.p2p.{p2p_bucket_vals,p2p_stream_vals} kernel
                    dispatch (the Pallas launch seam)
  memo.upload       api.DeviceMemo.__call__ miss path (host->device upload)
  dist.build_program dist.programs.build_exchange_program
  fused.launch      engine.DeviceEngine._evaluate_fused — fires a simulated
                    RESOURCE_EXHAUSTED (`InjectedResourceExhausted`)

Activation: the `inject_faults(...)` context manager, or `REPRO_FAULTS=`
in the environment (comma-separated `site[:count[:prob]]`, e.g.
`REPRO_FAULTS="exe_cache.compile:1"` — parsed once at import).  Arming an
unknown site raises immediately, so a typo cannot silently test nothing.

Disabled mode is zero-overhead in the obs tier's style: `fire(site)` is one
module-global load and a None test — no allocation, no dict lookup
(tracemalloc-pinned by tests/test_resilience.py).  Every fire is recorded
in a module-level ledger (`fired_counts`) that `analysis.check_counters`
reconciles against the fallback/typed-error ledgers: a fault that fires but
is neither absorbed by a counted fallback nor surfaced as a typed error is
an accounting violation, not a shrug.
"""
from __future__ import annotations

import os
import random
from contextlib import contextmanager

from repro import obs

__all__ = ["SITES", "InjectedFault", "InjectedResourceExhausted",
           "inject_faults", "fire", "arm", "disarm", "active_plan",
           "fired_counts", "fired_total", "reset_stats", "parse_spec"]

SITES = (
    "p2p.cache.read",
    "p2p.cache.write",
    "exe_cache.compile",
    "p2p.stream.tables",
    "kernels.p2p.launch",
    "memo.upload",
    "dist.build_program",
    "fused.launch",
)


class InjectedFault(RuntimeError):
    """A deliberately injected failure at a registered site.

    `transient=True` marks the fault as the retryable kind (a flaky compile,
    a transient device error): `fallback.call_with_retry` and the session
    ladder retry those with deterministic backoff instead of downgrading."""

    def __init__(self, site: str, *, transient: bool = False):
        super().__init__(f"injected fault at {site!r}")
        self.site = site
        self.transient = transient


class InjectedResourceExhausted(InjectedFault):
    """Simulated RESOURCE_EXHAUSTED on the fused launch path (the OOM an
    oversubscribed accelerator raises) — non-transient by construction, so
    the ladder downgrades instead of hammering the same allocation."""

    def __init__(self, site: str, *, transient: bool = False):
        super().__init__(site, transient=transient)
        self.args = (f"RESOURCE_EXHAUSTED (injected) at {site!r}",)


class _SiteState:
    __slots__ = ("remaining", "prob", "transient")

    def __init__(self, count, prob, transient):
        self.remaining = count          # None = unlimited
        self.prob = prob
        self.transient = transient


class FaultPlan:
    """Armed sites with per-site count/probability budgets and a seeded RNG
    (probabilistic plans are reproducible; count-only plans are exact)."""

    def __init__(self, spec: dict, seed: int = 0):
        unknown = sorted(set(spec) - set(SITES))
        if unknown:
            raise ValueError(f"unknown fault site(s) {unknown}; "
                             f"registered sites: {list(SITES)}")
        self._rng = random.Random(seed)
        self._sites = {}
        for site, cfg in spec.items():
            cfg = dict(cfg)
            count = cfg.pop("count", 1)
            prob = float(cfg.pop("prob", 1.0))
            transient = bool(cfg.pop("transient", False))
            if cfg:
                raise ValueError(f"unknown fault options {sorted(cfg)} "
                                 f"for site {site!r}")
            self._sites[site] = _SiteState(
                None if count is None else int(count), prob, transient)

    def maybe_raise(self, site: str) -> None:
        st = self._sites.get(site)
        if st is None or st.remaining == 0:
            return
        if st.prob < 1.0 and self._rng.random() >= st.prob:
            return
        if st.remaining is not None:
            st.remaining -= 1
        _FIRED[site] = _FIRED.get(site, 0) + 1
        obs.counter_add("faults.injected")
        if obs.enabled():
            obs.event("faults.fire", {"site": site,
                                      "transient": st.transient})
        cls = (InjectedResourceExhausted if site == "fused.launch"
               else InjectedFault)
        raise cls(site, transient=st.transient)


# Module state: None = disarmed (the common case — fire() is one global
# load + a None test, nothing else).
_PLAN: FaultPlan | None = None
_FIRED: dict = {}                       # site -> times fired (ledger)


def fire(site: str) -> None:
    """Hot-path hook at every registered seam: no-op unless a plan is armed.
    Call sites pass literal site names; an armed plan validates names at arm
    time, so this stays lookup-free when disarmed."""
    p = _PLAN
    if p is None:
        return
    p.maybe_raise(site)


def active_plan() -> FaultPlan | None:
    return _PLAN


def arm(plan: FaultPlan) -> None:
    global _PLAN
    _PLAN = plan


def disarm() -> None:
    global _PLAN
    _PLAN = None


def fired_counts() -> dict:
    return dict(_FIRED)


def fired_total() -> int:
    return sum(_FIRED.values())


def reset_stats() -> None:
    _FIRED.clear()


def parse_spec(text: str) -> dict:
    """Parse the REPRO_FAULTS grammar: comma-separated `site[:count[:prob]]`.
    `count` of `*` means unlimited.  Returns an `inject_faults`-shaped spec
    dict; raises ValueError on unknown sites or malformed entries."""
    spec: dict = {}
    for item in text.split(","):
        item = item.strip()
        if not item:
            continue
        parts = item.split(":")
        site = parts[0].strip()
        cfg: dict = {}
        if len(parts) > 1:
            cfg["count"] = None if parts[1] == "*" else int(parts[1])
        if len(parts) > 2:
            cfg["prob"] = float(parts[2])
        if len(parts) > 3:
            raise ValueError(f"malformed REPRO_FAULTS entry {item!r}")
        spec[site] = cfg
    if spec:
        FaultPlan(spec)                 # validate sites eagerly
    return spec


@contextmanager
def inject_faults(spec=None, *, seed: int = 0, **sites):
    """Arm a fault plan for the duration of the block.

        with inject_faults({"exe_cache.compile": {"count": 1}}):
            sess.evaluate()
        with inject_faults("memo.upload"): ...          # one shot, p=1
        with inject_faults(**{"fused.launch": {}}): ... # kwargs form

    Each site's config accepts `count` (fires at most N times; None =
    unlimited; default 1), `prob` (per-arrival firing probability, drawn
    from a RNG seeded by `seed`; default 1.0) and `transient` (mark fired
    faults retryable; default False).  Nested arming is rejected — a chaos
    test must own its plan."""
    if _PLAN is not None:
        raise RuntimeError("inject_faults: a fault plan is already armed")
    full: dict = {}
    if spec is not None:
        if isinstance(spec, str):
            full[spec] = {}
        else:
            full.update({k: dict(v) for k, v in dict(spec).items()})
    full.update({k: dict(v) for k, v in sites.items()})
    arm(FaultPlan(full, seed=seed))
    try:
        yield
    finally:
        disarm()


def _arm_from_env() -> None:
    text = os.environ.get("REPRO_FAULTS", "")
    if not text:
        return
    seed = int(os.environ.get("REPRO_FAULTS_SEED", "0"))
    arm(FaultPlan(parse_spec(text), seed=seed))


_arm_from_env()
