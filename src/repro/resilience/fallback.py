"""Graceful degradation: retry policy, the rung ladder, and the ledgers.

The ladder orders the evaluation paths that ALREADY exist in the stack,
fastest first, and a resilient `FMMSession` walks DOWN it when a rung
fails (see `api.FMMSession._evaluate_resilient`):

  dist        ShardedEngine over a shard_map mesh (exchange programs)
  streaming   DeviceEngine, streaming Pallas P2P (p2p_stream + kernels)
  gathered    DeviceEngine, gathered Pallas P2P buckets (kernels, no stream)
  xla_slab    DeviceEngine, XLA-only programs (stream slab gather / jnp
              buckets; no Pallas launch)
  per_phase   DeviceEngine, per-phase jnp execution (no fused megakernel)
  reference   host f64 per-partition executor (api.execute_geometry)

A dist failure (exchange-program build, collective execution, payload
checksum mismatch) drops the mesh and re-enters the ladder at whatever
single-device rung the session's knobs select — the "dist engine ->
single-device engine" arm.  Every downgrade is recorded three ways: the
session's `ResilienceState` (surfaced as `report()["resilience"]` with the
`degraded` flag), a `resilience.fallback` obs counter, and a warn-once
RuntimeWarning per (from, to) transition.  Transient errors (marked by a
`transient` attribute — e.g. `faults.InjectedFault(transient=True)`) are
retried in place with deterministic exponential backoff before any
downgrade; the clock is injectable so tests assert exact delays.

Module-level ledgers (`record_fallback` / `record_typed_error` /
`record_retry`) let `analysis.check_counters` gate the accounting identity
"every fired fault is either absorbed by a counted fallback or surfaced as
a typed `ResilienceError`" across whole processes, not just one session.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

from repro import obs

__all__ = ["LADDER", "ResilienceError", "ExchangeVerificationError",
           "RetryPolicy", "ResilienceState", "is_transient",
           "call_with_retry", "record_fallback", "record_typed_error",
           "record_retry", "fallback_total", "typed_error_total",
           "retry_total", "ledger_counts", "reset_ledger",
           "default_resilience_enabled"]

LADDER = ("dist", "streaming", "gathered", "xla_slab", "per_phase",
          "reference")


class ResilienceError(RuntimeError):
    """Terminal: the ladder is exhausted (or has no rung below the failing
    one) and the session cannot produce a trustworthy potential.  Carries
    the `site` of the originating failure — the injected site name for
    injected faults, the failing rung otherwise — so chaos tests assert
    exactly which seam surfaced."""

    def __init__(self, site: str, message: str):
        super().__init__(message)
        self.site = site
        record_typed_error(site)


class ExchangeVerificationError(RuntimeError):
    """A delivered wire span did not match its sender-side payload
    (REPRO_VERIFY_EXCHANGE=1 checksum audit).  Non-terminal: the ladder
    treats it like any dist failure and falls back to the single-device
    engine rather than serving a corrupted halo."""

    def __init__(self, site: str, message: str):
        super().__init__(message)
        self.site = site


@dataclass
class RetryPolicy:
    """Bounded retry with deterministic exponential backoff.  `sleep` is the
    injectable clock: tests pass a recorder and assert the exact delay
    sequence `base_delay * 2**k` capped at `max_delay`."""
    max_retries: int = 2
    base_delay: float = 0.05
    max_delay: float = 1.0
    sleep: object = None                # None -> time.sleep

    def delay(self, attempt: int) -> float:
        return min(self.base_delay * (2 ** attempt), self.max_delay)

    def pause(self, attempt: int) -> None:
        import time
        (time.sleep if self.sleep is None else self.sleep)(self.delay(attempt))


def is_transient(exc: BaseException) -> bool:
    """Retry-worthy errors carry an explicit `transient` marker; everything
    else (a real OOM, a table-build bug, a non-transient injected fault)
    goes straight to the downgrade path — retrying a deterministic failure
    just delays the fallback."""
    return bool(getattr(exc, "transient", False))


def call_with_retry(fn, *, site: str, policy: RetryPolicy | None = None,
                    state: "ResilienceState | None" = None):
    """Run `fn()`, retrying transient failures up to `policy.max_retries`
    times with deterministic backoff.  Non-transient errors propagate
    unchanged on first sight, so the wrapper costs one frame on the happy
    path and changes no semantics for ordinary exceptions."""
    policy = policy or RetryPolicy()
    attempt = 0
    while True:
        try:
            return fn()
        except Exception as exc:
            if not is_transient(exc) or attempt >= policy.max_retries:
                raise
            record_retry(site)
            if state is not None:
                state.retries += 1
            obs.counter_add("resilience.retries")
            if obs.enabled():
                obs.event("resilience.retry",
                          {"site": site, "attempt": attempt,
                           "delay_s": policy.delay(attempt)})
            policy.pause(attempt)
            attempt += 1


# ------------------------------------------------------ process ledgers ---
_FALLBACKS: dict = {}                   # site -> counted downgrades
_TYPED_ERRORS: dict = {}                # site -> ResilienceError raises
_RETRIES: dict = {}                     # site -> transient retries
_WARNED: set = set()                    # warn-once keys (site, frm, to)


def record_fallback(site: str, frm: str, to: str, *,
                    warn: bool = True) -> None:
    """Count one degradation (ladder downgrade or locally absorbed failure,
    e.g. autotune disk cache -> in-memory) and warn once per transition.
    `warn=False` for call sites that already emit their own warn-once
    (e.g. kernels.p2p's cache-degradation warning) — the ledger entry still
    lands either way."""
    _FALLBACKS[site] = _FALLBACKS.get(site, 0) + 1
    obs.counter_add("resilience.fallback")
    obs.counter_add(f"resilience.fallback.{frm}->{to}")
    if obs.enabled():
        obs.event("resilience.fallback", {"site": site, "from": frm,
                                          "to": to})
    key = (site, frm, to)
    if warn and key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(
            f"resilience: degrading {frm!r} -> {to!r} after failure at "
            f"{site!r} (counted at resilience.fallback; this transition "
            "warns once)", RuntimeWarning, stacklevel=3)


def record_typed_error(site: str) -> None:
    _TYPED_ERRORS[site] = _TYPED_ERRORS.get(site, 0) + 1
    obs.counter_add("resilience.typed_errors")


def record_retry(site: str) -> None:
    _RETRIES[site] = _RETRIES.get(site, 0) + 1


def fallback_total() -> int:
    return sum(_FALLBACKS.values())


def typed_error_total() -> int:
    return sum(_TYPED_ERRORS.values())


def retry_total() -> int:
    return sum(_RETRIES.values())


def ledger_counts() -> dict:
    return {"fallbacks": dict(_FALLBACKS), "typed_errors": dict(_TYPED_ERRORS),
            "retries": dict(_RETRIES)}


def reset_ledger() -> None:
    _FALLBACKS.clear()
    _TYPED_ERRORS.clear()
    _RETRIES.clear()
    _WARNED.clear()


def default_resilience_enabled() -> bool:
    import os
    return os.environ.get("REPRO_RESILIENCE", "").lower() in (
        "1", "on", "yes", "true")


# ------------------------------------------------------- session state ----
@dataclass
class ResilienceState:
    """Per-session resilience bookkeeping, surfaced verbatim (snapshot) as
    `FMMSession.report()["resilience"]`."""
    enabled: bool = False
    health_checks: bool = False
    rung: str | None = None             # committed rung of the last evaluate
    fallbacks: list = field(default_factory=list)
    retries: int = 0
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    health: dict = field(default_factory=lambda: {"checks": 0, "failures": 0})
    audits: dict = field(default_factory=lambda: {"checks": 0, "failures": 0})
    exchange_verified: int = 0

    @property
    def degraded(self) -> bool:
        return bool(self.fallbacks)

    def note_fallback(self, site: str, frm: str, to: str,
                      exc: BaseException | None) -> None:
        self.fallbacks.append({"site": site, "from": frm, "to": to,
                               "error": repr(exc) if exc is not None else None})
        record_fallback(site, frm, to)

    def snapshot(self) -> dict:
        return {"enabled": self.enabled, "degraded": self.degraded,
                "rung": self.rung, "fallbacks": list(self.fallbacks),
                "retries": self.retries,
                "health_checks": self.health_checks,
                "health": dict(self.health), "audits": dict(self.audits),
                "exchange_verified": self.exchange_verified}
