"""Tracer: nested host-wall-time spans with a Perfetto-loadable export.

The flight-recorder half of `repro.obs` (the other half is the metrics
registry, `obs.metrics`).  A `Tracer` records two event kinds:

  - **spans** — `with tracer.span("engine.upward"):` measures host wall time
    between enter and exit.  Spans nest (a per-thread stack tracks the open
    parent), carry a process-monotonic id, optional `key=value` attributes,
    and an optional *device fence*: `sp.fence(arrays)` registers JAX values
    to `block_until_ready` at span exit, so the recorded duration covers the
    device work the span launched rather than just the dispatch.  Fencing is
    opt-in per tracer (`fences=True`) AND per span — the fused single-launch
    paths stay unfenced by default, preserving the one-entry-launch
    guarantee's async pipelining.
  - **instant events** — `tracer.event("p2p.autotune", {...})` records a
    point-in-time marker (autotune decisions, cache events, probes).

Export: `to_chrome_trace()` renders the Chrome Trace Event Format (`"X"`
duration events + `"i"` instants) that both `chrome://tracing` and Perfetto
(https://ui.perfetto.dev) load directly; `summary()` aggregates span wall
time by name for `FMMSession.report()`.

Disabled mode lives one layer up: `repro.obs.span()` returns the shared
`NULL_SPAN` singleton when no tracer is installed — zero allocations, no
clock reads — which the overhead test pins (`tests/test_obs.py`).  The
classes here therefore never check an enabled flag themselves.
"""
from __future__ import annotations

import itertools
import os
import threading
import time

__all__ = ["Span", "NullSpan", "NULL_SPAN", "Tracer"]


class NullSpan:
    """The do-nothing span served while tracing is disabled.  A process-wide
    singleton (`NULL_SPAN`): entering, exiting, annotating and fencing all
    return immediately without allocating, so instrumented hot paths cost a
    dict lookup and an `is None` check when the recorder is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, attrs=None):
        return self

    def fence(self, value):
        return value


NULL_SPAN = NullSpan()


class Span:
    """One recorded interval.  Times are `time.perf_counter_ns` ticks
    relative to the owning tracer's epoch; `sid`/`parent` are the tracer's
    monotonic span ids (parent -1 = top level)."""

    __slots__ = ("tracer", "name", "attrs", "sid", "parent", "tid",
                 "t0_ns", "t1_ns", "_fenced")

    def __init__(self, tracer, name, attrs, sid, parent, tid):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.sid = sid
        self.parent = parent
        self.tid = tid
        self.t0_ns = -1
        self.t1_ns = -1
        self._fenced = None

    def set(self, attrs=None):
        """Merge `attrs` into the span's attributes (post-hoc annotation:
        results only known at the end of the measured region)."""
        if attrs:
            if self.attrs is None:
                self.attrs = dict(attrs)
            else:
                self.attrs.update(attrs)
        return self

    def fence(self, value):
        """Register `value` (any pytree of JAX arrays) to be
        `block_until_ready`-fenced at span exit — only when the tracer was
        built with `fences=True`; otherwise a pass-through no-op.  Returns
        `value` so call sites can fence inline: `out = sp.fence(fn())`."""
        if self.tracer.fences:
            self._fenced = value
        return value

    def __enter__(self):
        self.tracer._push(self)
        self.t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._fenced is not None:
            import jax
            jax.block_until_ready(self._fenced)
            self._fenced = None
        self.t1_ns = time.perf_counter_ns()
        self.tracer._pop(self)
        return False

    @property
    def dur_s(self) -> float:
        return (self.t1_ns - self.t0_ns) / 1e9


class Tracer:
    """Span + instant-event recorder.

    Parameters
    ----------
    fences : honor `Span.fence` registrations with a `block_until_ready` at
        span exit (per-phase *device* timing).  Off by default so traced
        sessions keep the exact async dispatch behavior of untraced ones.
    max_events : ring bound on retained finished events; the oldest half is
        dropped when exceeded (a flight recorder must never OOM the flight).
    """

    def __init__(self, *, fences: bool = False, max_events: int = 100_000):
        self.fences = bool(fences)
        self.max_events = int(max_events)
        self.epoch_ns = time.perf_counter_ns()
        self.events: list = []          # finished Spans + instant dicts
        self.dropped = 0
        self._ids = itertools.count()
        self._tls = threading.local()
        self._lock = threading.Lock()

    # ------------------------------------------------------------- record --
    def _stack(self):
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, attrs=None) -> Span:
        st = self._stack()
        parent = st[-1].sid if st else -1
        return Span(self, name, dict(attrs) if attrs else None,
                    next(self._ids), parent, threading.get_ident())

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        else:                            # tolerate misnested exits
            try:
                st.remove(span)
            except ValueError:
                pass
        self._record(span)

    def event(self, name: str, attrs=None) -> None:
        """Record an instant event at the current time."""
        st = self._stack()
        self._record({"name": name,
                      "attrs": dict(attrs) if attrs else None,
                      "sid": next(self._ids),
                      "parent": st[-1].sid if st else -1,
                      "tid": threading.get_ident(),
                      "t_ns": time.perf_counter_ns()})

    def _record(self, ev) -> None:
        with self._lock:
            self.events.append(ev)
            if len(self.events) > self.max_events:
                drop = len(self.events) // 2
                del self.events[:drop]
                self.dropped += drop

    # ------------------------------------------------------------- export --
    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.dropped = 0
        self.epoch_ns = time.perf_counter_ns()

    def spans(self, name: str | None = None) -> list:
        """Finished spans, oldest first, optionally filtered by name."""
        with self._lock:
            evs = list(self.events)
        return [e for e in evs if isinstance(e, Span)
                and (name is None or e.name == name)]

    def summary(self) -> dict:
        """Aggregate wall time by span name:
        {name: {count, total_s, mean_s, max_s}} — the `timings` block of
        `FMMSession.report()`."""
        agg: dict = {}
        for s in self.spans():
            a = agg.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                        "max_s": 0.0})
            d = s.dur_s
            a["count"] += 1
            a["total_s"] += d
            a["max_s"] = max(a["max_s"], d)
        for a in agg.values():
            a["mean_s"] = a["total_s"] / a["count"]
        return agg

    def to_chrome_trace(self) -> dict:
        """Chrome Trace Event Format JSON (dict — `json.dump` it).  Loadable
        by Perfetto (ui.perfetto.dev) and chrome://tracing: spans become
        complete ("X") duration events, instants become "i" events; `ts` and
        `dur` are microseconds since the tracer epoch."""
        pid = os.getpid()
        out = []
        with self._lock:
            evs = list(self.events)
        for e in evs:
            if isinstance(e, Span):
                rec = {"name": e.name, "cat": "span", "ph": "X",
                       "ts": (e.t0_ns - self.epoch_ns) / 1e3,
                       "dur": (e.t1_ns - e.t0_ns) / 1e3,
                       "pid": pid, "tid": e.tid,
                       "args": {"sid": e.sid, "parent": e.parent,
                                **(e.attrs or {})}}
            else:
                rec = {"name": e["name"], "cat": "event", "ph": "i",
                       "s": "t",
                       "ts": (e["t_ns"] - self.epoch_ns) / 1e3,
                       "pid": pid, "tid": e["tid"],
                       "args": {"sid": e["sid"], "parent": e["parent"],
                                **(e["attrs"] or {})}}
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}
