"""Process-wide metrics registry: counters, gauges, histograms.

The always-on half of `repro.obs`.  Where spans answer "where did the wall
time go", metrics answer "how many / how much": XLA compiles, cache hits,
device uploads, exchange bytes, autotune decisions.  Three instrument kinds:

  - **counter** — monotonically increasing float (`counter_add`).
  - **gauge** — last-write-wins float (`gauge_set`).
  - **histogram** — streaming count/sum/min/max of observations (`observe`);
    no buckets — the report surface wants summary stats, not percentiles,
    and bucketless updates keep the hot path to a dict lookup + 4 updates.

All updates go through `repro.obs` module-level helpers which no-op (zero
allocations) when observability is disabled; the registry itself never
checks an enabled flag.  `snapshot()` returns plain nested dicts for
`FMMSession.report()`; `reset()` restores a pristine registry (used by the
autouse test fixture so counter assertions can't leak between tests).
"""
from __future__ import annotations

import threading

__all__ = ["MetricsRegistry", "GLOBAL_METRICS"]


class MetricsRegistry:
    """Named counters/gauges/histograms behind one lock.

    Names are flat dotted strings (`"exe_cache.miss"`, `"dist.wire_bytes"`).
    A name lives in exactly one instrument family — re-using a counter name
    as a gauge raises, catching instrumentation typos early.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._hists: dict = {}

    def _check_unique(self, name, family):
        for fam, store in (("counter", self._counters),
                           ("gauge", self._gauges),
                           ("histogram", self._hists)):
            if fam != family and name in store:
                raise ValueError(
                    f"metric {name!r} already registered as a {fam}")

    # ---------------------------------------------------------- updates --
    def counter_add(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            if name not in self._counters:
                self._check_unique(name, "counter")
                self._counters[name] = 0.0
            self._counters[name] += value

    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            if name not in self._gauges:
                self._check_unique(name, "gauge")
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                self._check_unique(name, "histogram")
                h = self._hists[name] = {"count": 0, "sum": 0.0,
                                         "min": float("inf"),
                                         "max": float("-inf")}
            h["count"] += 1
            h["sum"] += value
            if value < h["min"]:
                h["min"] = value
            if value > h["max"]:
                h["max"] = value

    # ------------------------------------------------------------ reads --
    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def gauge(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._gauges.get(name, default)

    def histogram(self, name: str) -> dict | None:
        with self._lock:
            h = self._hists.get(name)
            return dict(h) if h is not None else None

    def snapshot(self) -> dict:
        """Plain-dict copy: {"counters": {...}, "gauges": {...},
        "histograms": {name: {count,sum,min,max,mean}}}."""
        with self._lock:
            hists = {}
            for name, h in self._hists.items():
                d = dict(h)
                d["mean"] = d["sum"] / d["count"] if d["count"] else 0.0
                hists[name] = d
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "histograms": hists}

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


# One process-wide registry: instrumentation across tiers accumulates into
# the same namespace so `FMMSession.report()` sees everything.
GLOBAL_METRICS = MetricsRegistry()
