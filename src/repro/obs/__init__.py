"""repro.obs — the flight recorder: tracing + metrics for every tier.

One switchboard in front of two instruments:

  - `obs.trace.Tracer` — nested host-wall-time spans (optional
    `block_until_ready` fences for device timing) + instant events, exported
    as Perfetto-loadable chrome-trace JSON.
  - `obs.metrics.MetricsRegistry` — process-wide counters/gauges/histograms.

Instrumented code never talks to either directly; it calls the module-level
helpers below::

    from repro import obs
    with obs.span("plan.partition", {"nparts": nparts}) as sp:
        parts = partition(...)
        sp.set({"max_part": int(counts.max())})
    obs.counter_add("plan.builds")
    obs.event("p2p.autotune", {"S": S, "choice": best})

**Disabled is the default and must cost nothing.**  When tracing is off,
`span()` returns the shared `NULL_SPAN` singleton and `event` /
`counter_add` / `gauge_set` / `observe` return immediately — no allocations
(attrs are a positional arg, never `**kwargs`), no clock reads, no locks.
`tests/test_obs.py` pins zero allocations per disabled call with
tracemalloc.  Because of this contract, helpers take `attrs` as an
*already-built dict or None*; call sites must not build attr dicts
unconditionally on hot paths — gate them on `obs.enabled()` or pass None.

Enable programmatically::

    obs.configure(enabled=True)            # spans + metrics, no fences
    obs.configure(enabled=True, fences=True)   # per-phase device timing

or via environment (read once at import): ``REPRO_TRACE=1`` enables,
``REPRO_TRACE_FENCES=1`` additionally fences span boundaries.  Fences are
opt-in because they serialize the async dispatch stream — the fused
single-launch serving path should be measured unfenced (dispatch cost)
unless you explicitly want per-phase device occupancy.

`configure(enabled=False)` detaches the tracer but leaves recorded history
readable via `get_tracer()`; `reset()` clears spans, events and metrics
(the test-isolation hook).
"""
from __future__ import annotations

import os as _os

from .trace import NULL_SPAN, NullSpan, Span, Tracer
from .metrics import GLOBAL_METRICS, MetricsRegistry

__all__ = [
    "Tracer", "Span", "NullSpan", "NULL_SPAN",
    "MetricsRegistry", "GLOBAL_METRICS",
    "configure", "enabled", "fences_enabled", "get_tracer", "reset",
    "span", "event", "fence",
    "counter_add", "gauge_set", "observe", "metrics_snapshot",
]

# Module state.  `_TRACER is None` IS the disabled flag — the hot-path check
# is one global load + identity test.
_TRACER: Tracer | None = None
_LAST_TRACER: Tracer | None = None      # history stays readable after disable


def configure(enabled: bool = True, *, fences: bool = False,
              max_events: int = 100_000) -> Tracer | None:
    """Install (or detach) the process tracer.  Returns the active tracer,
    or None when disabling.  Re-configuring replaces the tracer — prior
    history remains readable through `get_tracer()` until the next enable."""
    global _TRACER, _LAST_TRACER
    if enabled:
        _TRACER = Tracer(fences=fences, max_events=max_events)
        _LAST_TRACER = _TRACER
    else:
        _TRACER = None
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


def fences_enabled() -> bool:
    return _TRACER is not None and _TRACER.fences


def get_tracer() -> Tracer | None:
    """The active tracer, or the most recently active one (so reports can
    still read history after `configure(enabled=False)`), or None."""
    return _TRACER if _TRACER is not None else _LAST_TRACER


def reset() -> None:
    """Clear all recorded spans/events and zero every metric.  Used by the
    autouse test fixture for inter-test isolation."""
    global _LAST_TRACER
    if _TRACER is not None:
        _TRACER.clear()
    elif _LAST_TRACER is not None:
        _LAST_TRACER = None
    GLOBAL_METRICS.reset()


# ------------------------------------------------------------- hot path --
def span(name: str, attrs=None):
    """Context manager measuring the enclosed host wall time.  Disabled →
    the shared NULL_SPAN (no allocation)."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, attrs)


def event(name: str, attrs=None) -> None:
    """Record an instant event (autotune decision, cache event, probe)."""
    t = _TRACER
    if t is None:
        return
    t.event(name, attrs)


def fence(value):
    """`block_until_ready(value)` iff fencing is configured; returns value.
    For call sites that want a fence *between* operations rather than at a
    span boundary."""
    t = _TRACER
    if t is not None and t.fences:
        import jax
        jax.block_until_ready(value)
    return value


def counter_add(name: str, value: float = 1.0) -> None:
    if _TRACER is None:
        return
    GLOBAL_METRICS.counter_add(name, value)


def gauge_set(name: str, value: float) -> None:
    if _TRACER is None:
        return
    GLOBAL_METRICS.gauge_set(name, value)


def observe(name: str, value: float) -> None:
    if _TRACER is None:
        return
    GLOBAL_METRICS.observe(name, value)


def metrics_snapshot() -> dict:
    return GLOBAL_METRICS.snapshot()


# Environment opt-in, read once at import: REPRO_TRACE=1 [REPRO_TRACE_FENCES=1]
if _os.environ.get("REPRO_TRACE", "").strip() in ("1", "true", "on"):
    configure(enabled=True,
              fences=_os.environ.get("REPRO_TRACE_FENCES", "").strip()
              in ("1", "true", "on"))
