"""Particle distributions used by the paper's experiments.

`sphere` (boundary/surface — the paper's main target, ~50% of FMM use via
boundary integral equations), `cube` (uniform volume — classical case where
HOT is optimal), `ellipsoid` (PVFMM comparison, Fig 9), `plummer` (astro).
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_distribution"]


def make_distribution(kind: str, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "cube":
        return rng.uniform(-1, 1, (n, 3))
    if kind == "sphere":
        v = rng.normal(size=(n, 3))
        return v / np.linalg.norm(v, axis=1, keepdims=True)
    if kind == "ellipsoid":
        v = rng.normal(size=(n, 3))
        v /= np.linalg.norm(v, axis=1, keepdims=True)
        return v * np.array([2.0, 1.0, 0.5])
    if kind == "plummer":
        # Plummer model with unit scale radius, clipped to 10 radii
        m = rng.uniform(0, 1, n)
        r = np.minimum((m ** (-2.0 / 3.0) - 1.0) ** -0.5, 10.0)
        v = rng.normal(size=(n, 3))
        v /= np.linalg.norm(v, axis=1, keepdims=True)
        return v * r[:, None]
    raise ValueError(f"unknown distribution {kind!r}")
