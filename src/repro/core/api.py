"""Layered distributed-FMM API: GeometryPlan -> CommSchedule -> FMMSession.

The paper's contributions are independent axes — partitioning (§3),
communication granularity (§4.1) and exchange protocol (§4.2–4.3) — and this
facade keeps them composable instead of entangled:

  1. `plan_geometry(x, q, PartitionSpec) -> GeometryPlan` — ALL host-side
     geometry, built once with no protocol argument: partitioning, completely
     local trees, batched sender-side LET extraction (`extract_lets` runs
     exactly once per sender for all P-1 remote boxes), per-receiver frozen
     interaction plans against every grafted subtree, and the (P, P) bytes
     matrix.
  2. `schedule_comm(geometry, protocol, ...) -> CommSchedule` — a cheap pure
     function over the frozen bytes matrix and Lemma-1 adjacency boxes.
     Sweeping all four protocols reuses one `GeometryPlan` with zero
     re-partitioning, re-treeing or re-extraction.
  3. `FMMSession` — holds a `GeometryPlan` plus memoized device-resident
     views of its frozen NumPy index tables (`DeviceMemo`: every table is
     uploaded exactly once, so executions after the first perform zero
     host->device transfers of plan tables).  `.potentials(protocol=...)`
     evaluates once per geometry version, `.sweep()` serves all protocols
     from that one evaluation, and `.step(new_x)` revalidates the cached
     plan through MAC slack margins and rebuilds only invalidated
     partitions (time-stepped N-body with slowly drifting geometry).
     Evaluation dispatches to the batched device engine
     (repro.core.engine.DeviceEngine — one launch per FMM phase for the
     whole geometry, Pallas-bucketed P2P, multipoles device-resident across
     steps) when `engine=True` (default on device backends), else to the
     per-partition reference executor `execute_geometry`.

MAC slack revalidation (`FMMSession.step`)
------------------------------------------
Every structural decision in a plan is a strict inequality with a margin:
M2L pairs were accepted with  R_A + R_B < theta * d  (margin
m = theta*d - R_A - R_B > 0) and LET truncations with  2R < theta * dist
(margin theta*dist - 2R).  If every body of a partition moves by at most
delta, tight-cell centers shift and radii grow by at most sqrt(3) * delta,
so a sufficient condition for every accepted decision of a pair (i, j) to
remain valid is  delta_i + delta_j <= m / (sqrt(3) * (1 + theta)).  The
per-partition slack budget is therefore

    slack_j = min(margins touching j) / (2 * sqrt(3) * (1 + theta))

(the factor 2 splits the pair budget).  A partition whose drift since the
plan's reference positions stays within its slack keeps its tree topology,
interaction lists and LET structure; only the numeric payload (coordinates,
charges, multipoles) is rebound — expansion centers deliberately stay at
their build-time positions, which keeps P2M/M2L/L2P mutually consistent
while the slack bounds the extra truncation error.  A partition that
exceeds its slack is rebuilt, together with every LET and receiver plan
that touches it; untouched partitions are reused as-is.

The legacy entry points `run_distributed_fmm` / `build_distributed_plan`
(repro.core.distributed_fmm) are deprecated shims over these layers, pinned
byte-identical by golden tests.
"""
from __future__ import annotations

import math
import os
import weakref
from dataclasses import dataclass, field, replace as dc_replace

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import protocols as proto
from repro.resilience import fallback as _rfb
from repro.resilience import faults as _rfaults
from repro.core.fmm import (_resolve_kernels, downward_pass, l2p_pass,
                            m2l_apply, m2p_apply, p2p_apply, upward_pass)
from repro.core.hsdx import adjacency_from_boxes, graph_diameter
from repro.core.let import LETData, extract_lets, graft, refresh_let
from repro.core.multipole import get_operators
from repro.core.partition.hot import hot_partition
from repro.core.partition.orb import orb_partition
from repro.core.plan import (InteractionPlan, TreeSchedules,
                             build_interaction_plan, build_tree_schedules)
from repro.core.tree import build_tree

__all__ = ["PartitionSpec", "GeometryPlan", "CommSchedule", "SessionResult",
           "StepReport", "RemoteBlock", "ReceiverPlan", "DeviceMemo",
           "plan_geometry", "schedule_comm", "execute_geometry", "FMMSession",
           "sync_host_multipoles", "DEFAULT_SFC_BOX_INFLATION"]

# default eps-inflation of SFC partitions' tight boxes when deriving the
# adjacency graph (fraction of the global span); ORB regions share split
# planes exactly and need no inflation
DEFAULT_SFC_BOX_INFLATION = 0.03

_EMPTY_LO, _EMPTY_HI = np.inf, -np.inf      # empty-partition box sentinel


# ------------------------------------------------------------------ specs --
@dataclass(frozen=True)
class PartitionSpec:
    """Protocol-independent geometry parameters: everything `plan_geometry`
    needs, and nothing `schedule_comm` cares about.

    `traversal_backend`: where dual traversal + MAC margin scoring run —
    "host" (NumPy frontier reference), "device" (lax.while_loop + Pallas MAC
    kernel, repro.core.engine.traversal), or None/"auto" (device whenever an
    accelerator backend is present, host on CPU)."""
    nparts: int = 8
    method: str = "orb"          # "orb" | "hilbert" | "morton"
    theta: float = 0.5
    ncrit: int = 64
    p: int = 4
    sfc_box_inflation: float = DEFAULT_SFC_BOX_INFLATION
    traversal_backend: str | None = None


@dataclass
class RemoteBlock:
    """One sender's grafted LET at one receiver: the frozen interaction plan
    plus the minimum M2L MAC margin (absolute units) for slack revalidation."""
    sender: int
    graft: object                # let._GraftedTree view over lets[(sender, j)]
    inter: InteractionPlan
    margin: float


@dataclass
class ReceiverPlan:
    """One partition's frozen receiver-side geometry."""
    tree: object
    sched: TreeSchedules
    local: InteractionPlan       # own tree vs own tree
    local_margin: float
    remote: list                 # [RemoteBlock], ascending sender id


@dataclass
class GeometryPlan:
    """Layer 1: every protocol-independent artifact, built once per geometry.

    Frozen in spirit — nothing mutates a GeometryPlan in place;
    `FMMSession.step` derives a successor that shares all untouched
    components and bumps `version`."""
    spec: PartitionSpec
    n: int
    x0: np.ndarray               # (N, 3) current positions, original order
    q0: np.ndarray               # (N,)   current charges
    x_ref: np.ndarray            # (N, 3) positions each partition's structure
                                 #        was built from (slack reference)
    part: np.ndarray
    owners: list                 # per-partition original body indices
    boxes: np.ndarray            # (P, 2, 3) tight boxes (empty => sentinel)
    adj_boxes: np.ndarray        # (P, 2, 3) Lemma-1 adjacency boxes
    trees: list                  # Tree per partition (None if empty)
    scheds: list                 # TreeSchedules per partition (None if empty)
    Ms: list                     # per-partition multipoles, NumPy (None if empty)
    lets: dict                   # (i, j) -> LETData
    receivers: list              # ReceiverPlan per partition (None if empty)
    bytes_matrix: np.ndarray     # (P, P) LET bytes i -> j
    adjacency_degree: float
    diameter: int
    slack: np.ndarray            # (P,) per-partition MAC drift budget
    partition_stats: dict = field(default_factory=dict)
    version: int = 0
    # Partitions whose *host-side* numeric mirrors (Ms, LET payloads, grafted
    # views) are deferred: an engine-backed session recomputes multipoles on
    # device during within-slack steps, so the NumPy mirrors are only filled
    # when the reference path actually needs them (sync_host_multipoles).
    # Structure, margins, slack and the bytes matrix are never stale.
    Ms_stale: tuple = ()

    @property
    def nparts(self) -> int:
        return self.spec.nparts

    @property
    def theta(self) -> float:
        return self.spec.theta

    @property
    def p(self) -> int:
        return self.spec.p


@dataclass(frozen=True)
class CommSchedule:
    """Layer 2: one protocol's schedule over a frozen GeometryPlan."""
    protocol: str
    schedule: proto.Schedule
    stats: dict
    loggp_time: float
    grain_bytes: int | None

    @property
    def n_stages(self) -> int:
        return self.schedule.n_stages


@dataclass(frozen=True)
class SessionResult:
    """One protocol's end-to-end answer: the (shared) potential plus this
    protocol's communication accounting."""
    phi: np.ndarray
    protocol: str
    comm: CommSchedule
    bytes_matrix: np.ndarray
    partition_stats: dict
    adjacency_degree: float
    diameter: int

    @property
    def schedule_stats(self) -> dict:
        return self.comm.stats

    @property
    def loggp_time(self) -> float:
        return self.comm.loggp_time

    @property
    def n_stages(self) -> int:
        return self.comm.n_stages


@dataclass(frozen=True)
class StepReport:
    """What `FMMSession.step` did: which partitions kept their cached
    structure, which were numerically refreshed, which were rebuilt."""
    cache_hit: bool              # True iff nothing changed at all
    rebuilt: tuple               # partitions whose drift exceeded their slack
    refreshed: tuple             # structure kept; payload rebound
    shift: tuple                 # per-partition max drift vs x_ref
    slack: tuple                 # per-partition budget the shift was tested against
    version: int                 # geometry version after the step


# ------------------------------------------------------------ device memo --
class DeviceMemo:
    """Memoized host->device uploads keyed by (array identity, dtype).

    Drop-in for `jnp.asarray` in the fmm executors: the first execution
    uploads each frozen plan table once; later executions reuse the cached
    device view (zero transfers).  Entries are anchored by a *weak*
    reference to the host array: while the array lives, `id()` stays unique
    and the view is served from cache; when a `step` replaces it (new
    positions, multipoles, LET payloads) and the old geometry is dropped,
    the entry self-evicts — long-running sessions do not accumulate stale
    host or device buffers.

    Hook contract (`asarray=` in the executors and the engine)
    ----------------------------------------------------------
    Any replacement hook MUST return a **device array** (`jax.Array`) for
    every call — `hook(arr)` and `hook(arr, dtype)`.  Returning a NumPy
    array (or any host view) would type-check downstream, but every jitted
    kernel call would silently re-upload it, defeating the memoization the
    hook exists for and turning the "zero transfers after warmup" guarantee
    into a per-call transfer.  The executors therefore wrap every hook in
    `fmm.device_hook`, which raises `TypeError` on a non-`jax.Array` return
    instead of degrading silently.  `misses` counts actual uploads and
    `hits` counts served cache views, so `misses` is the session's
    host->device transfer meter (tests pin it).

    NOTE: the executors call `jnp.asarray if asarray is None else asarray`
    — never `asarray or jnp.asarray`, which would silently drop an *empty*
    memo because `__len__` makes a fresh memo falsy."""

    def __init__(self):
        self._views: dict = {}
        self.hits = 0
        self.misses = 0

    def __call__(self, arr, dtype=None):
        if isinstance(arr, jax.Array):      # already device-resident
            return arr if dtype is None else jnp.asarray(arr, dtype)
        key = (id(arr), None if dtype is None else np.dtype(dtype).name)
        hit = self._views.get(key)
        if hit is not None:
            self.hits += 1
            obs.counter_add("memo.hits")
            return hit[1]
        _rfaults.fire("memo.upload")
        self.misses += 1
        obs.counter_add("memo.misses")
        if obs.enabled():
            a = np.asarray(arr)
            obs.event("memo.upload", {"nbytes": int(a.nbytes),
                                      "shape": list(a.shape),
                                      "dtype": str(a.dtype if dtype is None
                                                   else np.dtype(dtype))})
        # jnp.array (copy), not jnp.asarray: the CPU backend can alias the
        # host buffer on dtype-preserving uploads, which would keep replaced
        # arrays alive through the cached device view and defeat eviction
        dev = jnp.array(arr, dtype=dtype)
        try:
            anchor = weakref.ref(arr, lambda _, k=key: self._views.pop(k, None))
        except TypeError:                   # non-weakrefable input: pin it
            anchor = arr
        self._views[key] = (anchor, dev)
        return dev

    def is_resident(self, arr) -> bool:
        """True iff `arr` IS one of the memoized device views (identity, not
        equality).  The fused engine's donation guard: memo-resident views
        must never be donated to a launch — donation deletes the buffer and
        the memo would keep serving the dead view (see
        `engine.DeviceEngine._donatable` / `fmm.device_hook`)."""
        return any(view is arr for _, view in self._views.values())

    def __len__(self) -> int:
        return len(self._views)


# --------------------------------------------------------------- layer 1 ---
def _validate_geometry_inputs(x, q, spec: PartitionSpec) -> None:
    """Reject degenerate inputs at the API boundary with the offending
    argument NAMED, instead of failing deep inside partitioning (a zero-size
    reduction) or silently producing garbage (NaN coordinates survive the
    morton cast with only a RuntimeWarning).

    Deliberately NOT rejected: n < nparts.  Partitions holding no points are
    a supported configuration — they carry the empty-box sentinel
    (lo=+inf, hi=-inf) and are skipped by adjacency/LET extraction — and the
    paper's boundary distributions depend on that path (tests pin it)."""
    if x.ndim != 2 or x.shape[1] != 3:
        raise ValueError(f"x: expected positions of shape (n, 3), got "
                         f"{x.shape}")
    if len(x) == 0:
        raise ValueError("x: at least one body is required (got 0); empty "
                         "PARTITIONS are fine, an empty problem is not")
    if q.shape != (len(x),):
        raise ValueError(f"q: expected charges of shape ({len(x)},) to "
                         f"match x, got {q.shape}")
    if not np.isfinite(x).all():
        raise ValueError("x: positions contain non-finite values "
                         "(NaN or +-inf)")
    if not np.isfinite(q).all():
        raise ValueError("q: charges contain non-finite values (NaN or "
                         "+-inf)")
    if not spec.theta > 0.0:
        raise ValueError(f"theta: MAC opening angle must be > 0, got "
                         f"{spec.theta}")
    if spec.nparts < 1:
        raise ValueError(f"nparts: need at least one partition, got "
                         f"{spec.nparts}")


def _partition(x, nparts, method,
               sfc_box_inflation: float = DEFAULT_SFC_BOX_INFLATION):
    """Returns (part, tight_boxes, adjacency_boxes).  ORB regions share split
    planes exactly; SFC partitions fall back to eps-inflated tight boxes.
    Partitions holding no points carry the empty-box sentinel (lo=+inf,
    hi=-inf), which survives inflation and is skipped by Lemma-1 adjacency
    and LET extraction."""
    if method == "orb":
        part, tight, regions = orb_partition(x, nparts, regions=True)
        return part, tight, regions
    if method in ("hilbert", "morton"):
        part, _ = hot_partition(x, nparts, curve=method)
        boxes = np.empty((nparts, 2, 3))
        boxes[:, 0], boxes[:, 1] = _EMPTY_LO, _EMPTY_HI
        for p in range(nparts):
            pts = x[part == p]
            if len(pts):
                boxes[p, 0], boxes[p, 1] = pts.min(axis=0), pts.max(axis=0)
        span = (x.max(axis=0) - x.min(axis=0)).max()
        infl = boxes.copy()
        infl[:, 0] -= sfc_box_inflation * span
        infl[:, 1] += sfc_box_inflation * span
        return part, boxes, infl
    raise ValueError(method)


def _m2l_margin(inter: InteractionPlan, tgt, src, theta: float) -> float:
    """Min over the plan's valid M2L pairs of theta*d - (R_a + R_b) — the
    absolute distance the MAC has to spare before any accepted pair flips."""
    if inter.n_m2l == 0:
        return float("inf")
    a = inter.m2l_a[:inter.n_m2l]
    b = inter.m2l_b[:inter.n_m2l]
    d = np.linalg.norm(np.asarray(tgt.center)[a] - np.asarray(src.center)[b],
                       axis=1)
    return float(np.min(theta * d
                        - (np.asarray(tgt.radius)[a] + np.asarray(src.radius)[b])))


def _slack_budget(nparts: int, theta: float, receivers: list,
                  lets: dict) -> np.ndarray:
    """Per-partition drift budget from the minimum MAC / truncation margin of
    every plan and LET the partition participates in (module docstring)."""
    margin = np.full(nparts, np.inf)
    for j, r in enumerate(receivers):
        if r is None:
            continue
        margin[j] = min(margin[j], r.local_margin)
        for rb in r.remote:
            margin[rb.sender] = min(margin[rb.sender], rb.margin)
            margin[j] = min(margin[j], rb.margin)
    for (i, j), let in lets.items():
        margin[i] = min(margin[i], let.trunc_margin)
        margin[j] = min(margin[j], let.trunc_margin)
    return np.maximum(margin, 0.0) / (2.0 * math.sqrt(3.0) * (1.0 + theta))


def _geometry_pad_cells(trees) -> int | None:
    """One padded-cell envelope for every traversal of a geometry, so all
    (receiver, sender) pairs share a single traced device program (grafted
    LETs never exceed their sender's cell count)."""
    live = [t.n_cells for t in trees if t is not None]
    if not live:
        return None
    from repro.core.plan import bucket_size
    return bucket_size(max(live))


def _plan_pair(tgt, src, theta: float, with_m2p: bool, backend: str,
               pad_cells: int | None = None):
    """Traverse one (target, source) pair on the chosen backend and freeze
    its interaction plan; returns (inter, min accepted M2L margin).  The
    device path consumes the traversal's own margin output — no host NumPy
    margin recompute (`_m2l_margin` stays the host-path scorer)."""
    if backend == "device":
        from repro.core.engine.traversal import device_dual_traversal
        m2l, p2p, m2p, margin = device_dual_traversal(
            tgt, src, theta, with_m2p=True, pad_cells=pad_cells)
        assert with_m2p or len(m2p) == 0, \
            "truncated source cells require with_m2p=True"
        inter = build_interaction_plan(
            tgt, src, theta, with_m2p=with_m2p, m2l_pairs=m2l, p2p_pairs=p2p,
            m2p_pairs=(m2p if with_m2p else None))
        return inter, float(margin)
    inter = build_interaction_plan(tgt, src, theta, with_m2p=with_m2p)
    return inter, _m2l_margin(inter, tgt, src, theta)


def _remote_block(i: int, let: LETData, tree, theta: float,
                  backend: str = "host",
                  pad_cells: int | None = None) -> RemoteBlock:
    g = graft(let)
    inter, margin = _plan_pair(tree, g, theta, True, backend, pad_cells)
    return RemoteBlock(sender=i, graft=g, inter=inter, margin=margin)


def _rebind_remote(rb: RemoteBlock, let: LETData) -> RemoteBlock:
    """Rebind a drifted sender's refreshed LET payload onto the cached
    interaction plan: new graft view, same inter/margin (structure and MAC
    margins are drift-invariant within slack)."""
    return RemoteBlock(sender=rb.sender, graft=graft(let), inter=rb.inter,
                       margin=rb.margin)


def plan_geometry(x, q, spec: PartitionSpec | None = None,
                  **overrides) -> GeometryPlan:
    """Layer 1: partition, build local trees, extract every LET (one batched
    `extract_lets` call per sender), traverse every receiver pair — with no
    protocol argument.  Keyword overrides patch the spec:
    `plan_geometry(x, q, nparts=16, method="hilbert")`."""
    spec = dc_replace(spec or PartitionSpec(), **overrides)
    from repro.core.engine.traversal import resolve_traversal_backend
    backend = resolve_traversal_backend(spec.traversal_backend)
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    _validate_geometry_inputs(x, q, spec)
    n = len(x)
    P = spec.nparts
    with obs.span("plan.geometry") as sp_plan:
        with obs.span("plan.partition"):
            part, boxes, adj_boxes = _partition(
                x, P, spec.method, sfc_box_inflation=spec.sfc_box_inflation)
        ops = get_operators(spec.p)

        # --- completely local trees (local bounding box, tight cells; §3) --
        with obs.span("plan.trees"):
            owners, trees, scheds, Ms = [], [], [], []
            for pid in range(P):
                idx = np.nonzero(part == pid)[0]
                owners.append(idx)
                if len(idx) == 0:
                    trees.append(None)
                    scheds.append(None)
                    Ms.append(None)
                    continue
                t = build_tree(x[idx], q[idx], ncrit=spec.ncrit)
                trees.append(t)
                scheds.append(build_tree_schedules(t))
                Ms.append(np.asarray(upward_pass(t, ops, sched=scheds[-1])))

        # --- sender-initiated LET extraction: all remote boxes per sender in
        #     one batched frontier pass; empty partitions neither send nor
        #     receive -----------------------------------------------------
        with obs.span("plan.lets"):
            lets: dict[tuple[int, int], LETData] = {}
            B = np.zeros((P, P), dtype=np.int64)
            for i in range(P):
                if trees[i] is None:
                    continue
                others = np.array([j for j in range(P)
                                   if j != i and trees[j] is not None],
                                  dtype=np.int64)
                if len(others) == 0:
                    continue
                for j, let in zip(others, extract_lets(trees[i], Ms[i],
                                                       boxes[others, 0],
                                                       boxes[others, 1],
                                                       spec.theta)):
                    lets[(i, int(j))] = let
                    B[i, j] = let.nbytes

        # --- receiver side: graft + traverse ONCE into frozen plans --------
        with obs.span("plan.receivers"):
            pad_cells = _geometry_pad_cells(trees)
            receivers: list = []
            for j in range(P):
                if trees[j] is None:
                    receivers.append(None)
                    continue
                t = trees[j]
                local, local_margin = _plan_pair(t, t, spec.theta, False,
                                                 backend, pad_cells)
                remote = [_remote_block(i, lets[(i, j)], t, spec.theta,
                                        backend, pad_cells)
                          for i in range(P) if (i, j) in lets]
                receivers.append(ReceiverPlan(
                    tree=t, sched=scheds[j], local=local,
                    local_margin=local_margin, remote=remote))

        adj = adjacency_from_boxes(adj_boxes)
        deg = float(np.max([len(a) for a in adj]))
        obs.counter_add("plan.builds")
        if obs.enabled():
            sp_plan.set({"n": int(n), "nparts": int(P),
                         "method": spec.method, "backend": backend,
                         "let_bytes": int(B.sum())})
        return GeometryPlan(
            spec=spec, n=n, x0=x.copy(), q0=q.copy(), x_ref=x.copy(),
            part=part, owners=owners, boxes=boxes, adj_boxes=adj_boxes,
            trees=trees, scheds=scheds, Ms=Ms, lets=lets,
            receivers=receivers, bytes_matrix=B,
            adjacency_degree=deg, diameter=graph_diameter(adj),
            slack=_slack_budget(P, spec.theta, receivers, lets),
            partition_stats=dict(nparts=P, method=spec.method),
        )


# --------------------------------------------------------------- layer 2 ---
def schedule_comm(geometry, protocol: str = "hsdx",
                  prm: proto.LogGPParams | None = None,
                  grain_bytes: int | None = None,
                  check_delivery: bool = True) -> CommSchedule:
    """Layer 2: a pure function over the geometry's frozen bytes matrix and
    adjacency boxes — no partitioning, trees, traversal or LET work, so a
    protocol sweep costs four cheap schedule constructions, not four
    geometry builds."""
    B = geometry.bytes_matrix
    sched = proto.make_schedule(protocol, B, boxes=geometry.adj_boxes)
    if check_delivery:
        delivered = proto.simulate_delivery(sched)
        expect = {(i, j): int(B[i, j]) for i in range(len(B))
                  for j in range(len(B)) if i != j and B[i, j] > 0}
        if delivered != expect:
            raise RuntimeError(f"{protocol} failed to deliver the LET")
    return CommSchedule(
        protocol=protocol, schedule=sched, stats=proto.schedule_stats(sched),
        loggp_time=proto.loggp_time(sched, prm=prm, grain_bytes=grain_bytes),
        grain_bytes=grain_bytes)


# --------------------------------------------------------------- executor --
def sync_host_multipoles(geo) -> None:
    """Fill the deferred host-side numeric mirrors of `geo.Ms_stale`
    partitions: recompute their NumPy multipoles about the build-time
    expansion centers, rebind every LET payload they send, and re-graft the
    receiver views over the refreshed LETs.  In place — this is a cache
    fill (the values are exactly what an eager step would have produced),
    not a semantic mutation; no-op when nothing is stale."""
    stale = set(getattr(geo, "Ms_stale", ()))
    if not stale:
        return
    ops = get_operators(geo.spec.p)
    for j in sorted(stale):
        geo.Ms[j] = np.asarray(upward_pass(geo.trees[j], ops,
                                           sched=geo.scheds[j]))
    for (i, j), let in list(geo.lets.items()):
        if i in stale:
            geo.lets[(i, j)] = refresh_let(let, geo.trees[i], geo.Ms[i])
    for j, r in enumerate(geo.receivers):
        if r is None:
            continue
        if j not in stale and not any(rb.sender in stale for rb in r.remote):
            continue
        remote = [_rebind_remote(rb, geo.lets[(rb.sender, j)])
                  if rb.sender in stale else rb
                  for rb in r.remote]
        # rebind the receiver's own (payload-rebound) tree too: the deferred
        # step skipped the ReceiverPlan rebuild, so r.tree still references
        # pre-step coordinates
        geo.receivers[j] = ReceiverPlan(tree=geo.trees[j], sched=r.sched,
                                        local=r.local,
                                        local_margin=r.local_margin,
                                        remote=remote)
    geo.Ms_stale = ()


def execute_geometry(geo, use_kernels: bool = False, asarray=None,
                     use_pallas: bool | None = None) -> np.ndarray:
    """Reference executor — kernels + gathers only, one partition at a time:
    no traversal, no list building, no padding.  Works on any plan-shaped
    object (GeometryPlan or the legacy DistributedPlan).  With
    `asarray=DeviceMemo(...)`, every frozen index table is uploaded to the
    device at most once across calls.  The batched replacement lives in
    repro.core.engine (this path is what its golden tests pin against)."""
    use_kernels = _resolve_kernels(use_kernels, use_pallas, "execute_geometry")
    sync_host_multipoles(geo)
    ops = get_operators(geo.p)
    phi = np.zeros(geo.n)
    for j in range(geo.nparts):
        r = geo.receivers[j]
        if r is None:
            continue
        t = r.tree
        L = m2l_apply(ops, geo.Ms[j], r.local, asarray=asarray)
        phi_local = p2p_apply(t, t, r.local, use_kernels=use_kernels,
                              asarray=asarray)
        for rb in r.remote:
            if rb.inter.n_m2l:
                L = L + m2l_apply(ops, rb.graft.M, rb.inter, asarray=asarray)
            if rb.inter.n_p2p:
                phi_local += p2p_apply(t, rb.graft, rb.inter,
                                       use_kernels=use_kernels, asarray=asarray)
            if rb.inter.n_m2p:
                phi_local += m2p_apply(t, rb.graft.M, rb.inter, p=geo.p,
                                       asarray=asarray)
        L = downward_pass(t, ops, L, sched=r.sched, asarray=asarray)
        phi_local += l2p_pass(t, ops, L, sched=r.sched, asarray=asarray)
        phi[geo.owners[j][t.perm]] = phi_local
    return phi


# --------------------------------------------------------------- layer 3 ---
class FMMSession:
    """Layer 3: one geometry, all protocols, many timesteps.

    Holds a `GeometryPlan` plus a `DeviceMemo` of its frozen index tables:
    the first evaluation uploads each table once; every later evaluation is
    kernels-only with zero host->device plan transfers.  `potentials` caches
    the (protocol-independent) potential per geometry version, so
    `.sweep()` answers all four protocols from a single execution.

    Engine dispatch: with `engine=True` (the default whenever a device
    backend is present) evaluation runs through `repro.core.engine`'s
    `DeviceEngine` — one batched multi-tree upward launch, a segment-summed
    M2L over all (receiver, sender) pairs, and Pallas-bucketed P2P — and
    within-slack `.step()`s skip the per-partition host multipole refresh
    entirely: the engine restacks one (x, q) payload pair and recomputes
    every drifting partition's multipoles on device (the host-side NumPy
    mirrors are refilled lazily by `sync_host_multipoles` only if the
    reference path asks for them).  `engine=False` forces the per-partition
    reference executor (`execute_geometry`)."""

    def __init__(self, geometry: GeometryPlan, engine: bool | None = None,
                 use_kernels: bool | None = None,
                 use_pallas: bool | None = None,
                 fused: bool | None = None, exe_cache=None,
                 mesh=None, dist_protocol: str = "bulk",
                 dist_grain_bytes: int | None = None,
                 p2p_stream: bool | None = None,
                 resilience: bool | None = None,
                 health_checks: bool | None = None):
        from repro.core.engine import (default_engine_enabled,
                                       default_use_kernels)
        if use_pallas is not None:      # deprecated alias, warn-once + honor
            if use_kernels is not None:
                raise ValueError(
                    "pass use_kernels only; use_pallas is its deprecated "
                    "alias and conflicts when both are given")
            use_kernels = _resolve_kernels(False, use_pallas, "FMMSession")
        if not (hasattr(geometry, "receivers")
                and hasattr(geometry, "bytes_matrix")):
            raise ValueError(
                f"geometry: expected a GeometryPlan (plan_geometry(...) "
                f"output or plan-shaped object), got {type(geometry).__name__}")
        self._geo = geometry
        self.engine_enabled = (default_engine_enabled() if engine is None
                               else bool(engine))
        self.use_kernels = (default_use_kernels() if use_kernels is None
                            else bool(use_kernels))
        self.fused = fused               # None -> default_fused_enabled()
        self.p2p_stream = p2p_stream     # None -> default_p2p_stream()
        self.exe_cache = exe_cache       # None -> process-wide GLOBAL_CACHE
        self.mesh = mesh                 # 1-D mesh -> dist exchange dispatch
        if dist_protocol not in ("bulk", "grain", "hsdx"):
            raise ValueError(f"unknown dist_protocol {dist_protocol!r}; "
                             "expected 'bulk', 'grain' or 'hsdx'")
        self.dist_protocol = dist_protocol
        self.dist_grain_bytes = dist_grain_bytes
        self.resilience = _rfb.ResilienceState(
            enabled=(_rfb.default_resilience_enabled() if resilience is None
                     else bool(resilience)),
            health_checks=bool(health_checks) if health_checks is not None
            else False)
        self._engine = None
        self._dist = None
        self._memo = DeviceMemo()
        self._comm_cache: dict = {}
        self._phi: np.ndarray | None = None
        self._phi_version = -1
        self._exchange_verified: set = set()

    @classmethod
    def from_points(cls, x, q, spec: PartitionSpec | None = None,
                    engine: bool | None = None,
                    use_kernels: bool | None = None,
                    use_pallas: bool | None = None,
                    fused: bool | None = None, exe_cache=None,
                    mesh=None, dist_protocol: str = "bulk",
                    dist_grain_bytes: int | None = None,
                    p2p_stream: bool | None = None,
                    resilience: bool | None = None,
                    health_checks: bool | None = None,
                    **overrides) -> "FMMSession":
        return cls(plan_geometry(x, q, spec, **overrides), engine=engine,
                   use_kernels=use_kernels, use_pallas=use_pallas,
                   fused=fused, exe_cache=exe_cache, mesh=mesh,
                   dist_protocol=dist_protocol,
                   dist_grain_bytes=dist_grain_bytes,
                   p2p_stream=p2p_stream, resilience=resilience,
                   health_checks=health_checks)

    @property
    def geometry(self) -> GeometryPlan:
        return self._geo

    @property
    def memo(self) -> DeviceMemo:
        return self._memo

    @property
    def engine(self):
        """The session's `DeviceEngine`, building it on first access (engine
        dispatch enabled) or returning None (reference dispatch)."""
        if not self.engine_enabled:
            return None
        if self._engine is None or self._engine.geo is not self._geo:
            from repro.core.engine import DeviceEngine
            # share the session memo: sess.memo.misses stays THE transfer
            # meter whichever dispatch path runs
            self._engine = DeviceEngine(self._geo,
                                        use_kernels=self.use_kernels,
                                        asarray=self._memo,
                                        fused=self.fused,
                                        exe_cache=self.exe_cache,
                                        p2p_stream=self.p2p_stream)
        return self._engine

    @property
    def dist(self):
        """The session's `ShardedEngine` (mesh dispatch), built on first
        access; None without a mesh.  Rebuilt automatically after a step
        that rebuilds any partition (structure changed)."""
        if self.mesh is None:
            return None
        if self._dist is None or self._dist.geo is not self._geo:
            from repro.core.dist import ShardedEngine
            self._dist = ShardedEngine(self._geo, self.mesh,
                                       grain_bytes=self.dist_grain_bytes)
        return self._dist

    @property
    def exchange_stats(self) -> dict:
        """Per-rank wire accounting of the session's dist protocol (measured
        moved/delivered bytes, rounds, padding) + its LogGP prediction."""
        if self.mesh is None:
            # Deprecation note: before PR 8 this raised RuntimeError on
            # mesh-less sessions while exe_cache_stats returned a dict; the
            # stats surface is now uniformly non-raising — a structured
            # disabled payload marks "no mesh" instead.
            return {"enabled": False, "protocol": self.dist_protocol,
                    "reason": "no mesh: pass FMMSession(mesh=...) for "
                              "multi-device exchange accounting",
                    "n_rounds": 0, "moved_bytes": 0, "delivered_bytes": 0,
                    "padded_wire_bytes": 0, "per_rank_sent": [],
                    "per_rank_recv": [], "grain_bytes": None,
                    "loggp_time": 0.0, "rank_bytes": []}
        st = dict(self.dist.exchange_stats(self.dist_protocol))
        st["enabled"] = True
        return st

    @property
    def exe_cache_stats(self) -> dict:
        """Hit/miss/eviction counters of the fused executable cache this
        session resolves against (the process-wide GLOBAL_CACHE unless a
        private `exe_cache=` was passed).  `misses` counts actual XLA
        compilations — a second same-shape-class geometry must not move it
        (the zero-recompile guarantee tests pin)."""
        from repro.core.engine import resolve_cache
        eng = self._engine
        cache = eng.exe_cache if eng is not None else resolve_cache(self.exe_cache)
        return cache.stats()

    def report(self, *, measure_exchange: bool | None = None,
               protocols=None, reps: int = 3) -> dict:
        """One structured flight-recorder dict for this session: per-span
        timings, metrics counters, memo/cache/launch accounting and — on
        mesh-backed sessions — per-protocol exchange stats with the
        `model_drift` ratio (measured wall time / LogGP-predicted time).

        `measure_exchange` controls whether exchanges are actually *run and
        timed* (defaults to tracing-enabled); when off, the exchange block
        carries the static byte/round accounting only.  Never raises on
        mesh-less or engine-less sessions — disabled sub-blocks are marked
        `{"enabled": False}` (same contract as `exchange_stats`)."""
        tracer = obs.get_tracer()
        rep: dict = {
            "obs": {"enabled": obs.enabled(),
                    "fences": obs.fences_enabled(),
                    "events": len(tracer.events) if tracer else 0,
                    "dropped": tracer.dropped if tracer else 0},
            "timings": tracer.summary() if tracer else {},
            "metrics": obs.metrics_snapshot(),
            "memo": {"hits": self._memo.hits, "misses": self._memo.misses,
                     "resident_views": len(self._memo._views)},
            "exe_cache": self.exe_cache_stats,
            "geometry": {"n": int(self._geo.n),
                         "nparts": int(self._geo.spec.nparts),
                         "version": int(self._geo.version),
                         "bytes_matrix_total":
                             int(self._geo.bytes_matrix.sum())},
            "resilience": self.resilience.snapshot(),
        }

        # Launch accounting: per compiled fused entry, observed call count
        # and the HLO-verified entry-computation count (the one-launch pin).
        eng = self._engine
        if eng is not None and getattr(eng, "_entries", None):
            from repro.analysis.hlo_walk import count_entry_launches
            launches: dict = {}
            for (kind, x64), (entry, _tabs) in eng._entries.items():
                launches[kind] = {
                    "calls": entry.calls,
                    "entry_computations":
                        count_entry_launches(entry.hlo_text),
                    "x64": bool(x64)}
            launches["fused_dispatches"] = len(eng.launch_log)
            rep["launches"] = launches
        else:
            rep["launches"] = {"enabled": False}

        # Exchange accounting (+ measured-vs-LogGP drift when measuring).
        if self.mesh is None:
            rep["exchange"] = {"enabled": False, "protocols": {}}
        else:
            do_measure = (obs.enabled() if measure_exchange is None
                          else bool(measure_exchange))
            names = tuple(protocols) if protocols else ("bulk", "grain",
                                                        "hsdx")
            per_proto = {}
            for name in names:
                if do_measure:
                    per_proto[name] = self.dist.measure_exchange(name,
                                                                 reps=reps)
                else:
                    per_proto[name] = self.dist.exchange_stats(name)
            rep["exchange"] = {"enabled": True,
                               "protocol": self.dist_protocol,
                               "measured": do_measure,
                               "protocols": per_proto}
        return rep

    # ------------------------------------------------------------- comm ---
    def comm(self, protocol: str = "hsdx", grain_bytes: int | None = None,
             prm: proto.LogGPParams | None = None,
             check_delivery: bool = True) -> CommSchedule:
        """Memoized `schedule_comm` (cache dropped when a step rebuilds any
        partition, i.e. whenever the bytes matrix can change)."""
        key = (protocol, grain_bytes, check_delivery)
        if prm is None and key in self._comm_cache:
            return self._comm_cache[key]
        cs = schedule_comm(self._geo, protocol, prm=prm,
                           grain_bytes=grain_bytes,
                           check_delivery=check_delivery)
        if prm is None:
            self._comm_cache[key] = cs
        return cs

    # ------------------------------------------------------- resilience ---
    def _current_rung(self) -> str:
        """Classify the session's knobs onto the degradation ladder
        (`fallback.LADDER`).  The mapping is the inverse of `_apply_rung`:
        applying a rung then classifying returns that same rung, which is
        what makes downgrades monotone."""
        if self.mesh is not None:
            return "dist"
        if not self.engine_enabled:
            return "reference"
        from repro.core.engine import (default_fused_enabled,
                                       default_p2p_stream)
        fused = (default_fused_enabled() if self.fused is None
                 else bool(self.fused))
        stream = (default_p2p_stream() if self.p2p_stream is None
                  else bool(self.p2p_stream))
        if self.use_kernels and stream:
            return "streaming"
        if self.use_kernels:
            return "gathered"
        if stream or fused:
            return "xla_slab"
        return "per_phase"

    def _apply_rung(self, rung: str) -> None:
        """Mutate the session knobs to the given ladder rung and drop the
        stale engine so the next evaluation rebuilds on the new path (the
        memo and executable cache are shared, so the rebuild reuses every
        uploaded table and compatible compiled entry)."""
        if rung == "streaming":
            self.use_kernels, self.p2p_stream = True, True
        elif rung == "gathered":
            self.use_kernels, self.p2p_stream = True, False
        elif rung == "xla_slab":
            self.use_kernels, self.p2p_stream = False, True
        elif rung == "per_phase":
            self.use_kernels, self.p2p_stream = False, False
            self.fused = False
        elif rung == "reference":
            self.engine_enabled = False
        else:                               # pragma: no cover - guarded
            raise ValueError(f"unknown ladder rung {rung!r}")
        self._engine = None

    def _downgrade(self, exc: BaseException) -> None:
        """Step one rung DOWN the ladder after `exc` killed the current one.
        Dist failures drop the mesh and re-enter at whatever single-device
        rung the knobs select; exhaustion below `reference` raises the
        terminal typed `ResilienceError` carrying the failing site."""
        frm = self._current_rung()
        site = getattr(exc, "site", frm)
        if frm == "dist":
            self.mesh = None
            self._dist = None
            to = self._current_rung()
        else:
            i = _rfb.LADDER.index(frm)
            if i + 1 >= len(_rfb.LADDER):
                raise _rfb.ResilienceError(
                    site, f"resilience ladder exhausted at {frm!r}: "
                          f"{exc}") from exc
            to = _rfb.LADDER[i + 1]
            self._apply_rung(to)
        self.resilience.note_fallback(site, frm, to, exc)

    def _phi_healthy(self, phi) -> bool:
        """Opt-in numerical sentinel: phi (and, engine dispatch, the cached
        device multipoles) must be finite.  A failure is treated like any
        rung failure — downgrade and recompute on the next rung."""
        st = self.resilience
        st.health["checks"] += 1
        ok = bool(np.isfinite(phi).all())
        if ok and self._engine is not None and self._engine._M is not None:
            ok = bool(np.isfinite(np.asarray(self._engine._M)).all())
        if not ok:
            st.health["failures"] += 1
            obs.counter_add("resilience.health_failures")
        return ok

    def _verify_exchange_once(self) -> None:
        """REPRO_VERIFY_EXCHANGE=1: checksum every delivered wire span
        against its sender-side payload, once per (protocol, geometry
        version).  Raises `ExchangeVerificationError` on mismatch — terminal
        without resilience, a dist->engine downgrade with it."""
        key = (self.dist_protocol, self._geo.version)
        if key in self._exchange_verified:
            return
        self.dist.verify_exchange(self.dist_protocol)
        self._exchange_verified.add(key)
        self.resilience.exchange_verified += 1

    def _dispatch_evaluate(self) -> tuple:
        """One evaluation attempt on the CURRENT rung -> (phi, dispatch)."""
        if self.mesh is not None:
            if os.environ.get("REPRO_VERIFY_EXCHANGE", "") in (
                    "1", "on", "yes", "true"):
                self._verify_exchange_once()
            return self.dist.evaluate(self.dist_protocol), "dist"
        if self.engine_enabled:
            return self.engine.evaluate(), "engine"
        return execute_geometry(self._geo, use_kernels=self.use_kernels,
                                asarray=self._memo), "reference"

    def _evaluate_resilient(self) -> tuple:
        """Walk the ladder until a rung produces a (healthy) potential.
        Transient failures retry in place with backoff; anything else costs
        one rung.  Terminates: every iteration either returns or strictly
        descends the finite ladder (`_downgrade` raises at the bottom)."""
        st = self.resilience
        while True:
            rung = self._current_rung()
            try:
                phi, dispatch = _rfb.call_with_retry(
                    self._dispatch_evaluate, site=rung,
                    policy=st.retry, state=st)
            except _rfb.ResilienceError:
                raise                       # already terminal + counted
            except Exception as exc:
                self._downgrade(exc)
                continue
            if st.health_checks and not self._phi_healthy(phi):
                exc = RuntimeError(
                    f"non-finite potential from rung {rung!r}")
                exc.site = "health.phi"
                self._downgrade(exc)
                continue
            st.rung = rung
            return phi, dispatch

    # ------------------------------------------------------------ kernels -
    def evaluate(self) -> np.ndarray:
        """Run the kernel pipeline now (ignoring the potential cache) against
        memoized device views; refreshes the cached potential.  Dispatches
        through the batched `DeviceEngine` when engine mode is on, else the
        per-partition reference executor.  With `resilience=True` a failing
        path degrades down `fallback.LADDER` instead of raising (see
        `_evaluate_resilient`).  The returned array is marked read-only: it
        is shared by every SessionResult of this geometry version, so
        in-place mutation would corrupt the cache — copy it to
        post-process."""
        with obs.span("session.evaluate") as sp:
            if self.resilience.enabled:
                phi, dispatch = self._evaluate_resilient()
            else:
                phi, dispatch = self._dispatch_evaluate()
            obs.counter_add("session.evaluations")
            if obs.enabled():
                sp.set({"dispatch": dispatch, "n": int(self._geo.n),
                        "version": int(self._geo.version)})
        phi.setflags(write=False)
        self._phi, self._phi_version = phi, self._geo.version
        return phi

    def potentials(self, protocol: str = "hsdx",
                   grain_bytes: int | None = None,
                   prm: proto.LogGPParams | None = None,
                   check_delivery: bool = True) -> SessionResult:
        """Potential (original body order) + this protocol's communication
        accounting.  The potential is protocol-independent and computed once
        per geometry version."""
        cs = self.comm(protocol, grain_bytes=grain_bytes, prm=prm,
                       check_delivery=check_delivery)
        if self._phi is None or self._phi_version != self._geo.version:
            self.evaluate()
        return SessionResult(
            phi=self._phi, protocol=protocol, comm=cs,
            bytes_matrix=self._geo.bytes_matrix,
            partition_stats=self._geo.partition_stats,
            adjacency_degree=self._geo.adjacency_degree,
            diameter=self._geo.diameter)

    def sweep(self, protocols=proto.PROTOCOLS,
              grain_bytes: int | None = None,
              prm: proto.LogGPParams | None = None,
              check_delivery: bool = True) -> dict:
        """All protocols from one GeometryPlan and one kernel execution."""
        return {name: self.potentials(name, grain_bytes=grain_bytes, prm=prm,
                                      check_delivery=check_delivery)
                for name in protocols}

    # ------------------------------------------------------------- step ---
    def step(self, new_x, new_q=None) -> StepReport:
        """Advance to new body positions/charges, reusing every cached
        structure the MAC slack margins still cover (module docstring).

        Unmoved bodies are a 100% cache hit: the geometry object, its
        version, the device memo and the cached potential are all untouched.
        Drift within a partition's slack rebinds that partition's numeric
        payload (positions, multipoles, shipped LET bodies) onto the cached
        index structure; drift beyond it rebuilds the partition and exactly
        the LETs / receiver plans that touch it."""
        with obs.span("session.step") as sp:
            report = self._step_impl(new_x, new_q)
            obs.counter_add("session.steps")
            if obs.enabled():
                sp.set({"cache_hit": report.cache_hit,
                        "rebuilt": len(report.rebuilt),
                        "refreshed": len(report.refreshed)})
        return report

    def _step_impl(self, new_x, new_q=None) -> StepReport:
        geo = self._geo
        spec = geo.spec
        P = spec.nparts
        new_x = np.array(new_x, dtype=np.float64)
        if new_x.shape != (geo.n, 3):
            raise ValueError(f"step: expected positions {(geo.n, 3)}, "
                             f"got {new_x.shape}")
        if not np.isfinite(new_x).all():
            raise ValueError("new_x: positions contain non-finite values "
                             "(NaN/Inf); refusing to poison the cached "
                             "geometry")
        q_unchanged = new_q is None
        new_q = geo.q0 if new_q is None else np.array(new_q, dtype=np.float64)
        if new_q.shape != (geo.n,):
            raise ValueError(f"step: expected charges {(geo.n,)}, "
                             f"got {new_q.shape}")
        if not np.isfinite(new_q).all():
            raise ValueError("new_q: charges contain non-finite values "
                             "(NaN/Inf)")
        q_unchanged = q_unchanged or np.array_equal(new_q, geo.q0)

        # Batched device revalidation: a warm engine scores every partition's
        # drift (and changed flag) in ONE launch from a single new_x upload —
        # the per-partition NumPy loop below is the host/reference path.  The
        # restacked device payload is reused as the next evaluation's payload.
        eng = (self._engine
               if self.engine_enabled and self._engine is not None
               and self._engine.geo is geo else None)
        use_dev = eng is not None and q_unchanged
        if use_dev:
            try:
                delta, stale = eng.step_drift(new_x)
            except Exception as exc:
                if not self.resilience.enabled:
                    raise
                # device revalidation died: fall through to the host f64
                # loop below — same answers, one rung slower, session lives
                self.resilience.note_fallback(
                    getattr(exc, "site", "engine.step_drift"),
                    "device_revalidation", "host", exc)
                use_dev = False
            if use_dev and np.any(stale & (delta > geo.slack
                                           - eng.drift_guard)):
                # a rebuild is coming OR a drift sits within the f32 guard
                # band of its slack: recompute drifts exactly (f64) on the
                # host — rebuild decisions and the conservative LET
                # re-extraction boxes must not ride f32 rounding
                use_dev = False
            if use_dev and self.resilience.health_checks:
                # Sampled MAC-slack audit: recompute up to 4 partitions'
                # drifts exactly (host f64) and require the device scores
                # to agree within the f32 guard band — a silent drift
                # underestimate is the one failure mode that serves a stale
                # potential as "cache hit".
                aud = self.resilience.audits
                sampled = [j for j in range(P) if len(geo.owners[j])][:4]
                for j in sampled:
                    idx = geo.owners[j]
                    exact = math.sqrt(float(
                        ((new_x[idx] - geo.x_ref[idx]) ** 2)
                        .sum(axis=1).max()))
                    aud["checks"] += 1
                    if abs(exact - float(delta[j])) > eng.drift_guard:
                        aud["failures"] += 1
                        obs.counter_add("resilience.audit_failures")
                        use_dev = False
                        break
        if not use_dev:
            if eng is not None:
                eng.discard_pending()
            delta = np.zeros(P)             # drift vs structure reference
            stale = np.zeros(P, dtype=bool)  # numeric payload out of date
            for j in range(P):
                idx = geo.owners[j]
                if len(idx) == 0:
                    continue
                delta[j] = math.sqrt(float(
                    ((new_x[idx] - geo.x_ref[idx]) ** 2).sum(axis=1).max()))
                stale[j] = (not np.array_equal(new_x[idx], geo.x0[idx])
                            or not np.array_equal(new_q[idx], geo.q0[idx]))

        rebuilt = tuple(int(j) for j in range(P)
                        if stale[j] and delta[j] > geo.slack[j])
        refreshed = tuple(int(j) for j in range(P)
                          if stale[j] and j not in rebuilt)
        report = StepReport(cache_hit=not (rebuilt or refreshed),
                            rebuilt=rebuilt, refreshed=refreshed,
                            shift=tuple(delta.tolist()),
                            slack=tuple(geo.slack.tolist()),
                            version=geo.version + bool(rebuilt or refreshed))
        if report.cache_hit:
            if eng is not None:
                eng.discard_pending()
            return report

        # Engine-backed sessions keep within-slack refreshes device-resident:
        # defer the per-partition host multipole/LET payload refresh (filled
        # lazily by sync_host_multipoles iff the reference path needs it) —
        # the engine recomputes every drifting partition's multipoles in one
        # batched launch from the restacked (x, q) payload.
        defer = self.engine_enabled and not rebuilt
        self._geo = self._advance(geo, new_x, new_q, delta,
                                  set(rebuilt), set(refreshed),
                                  defer_numeric=defer)
        self._phi = None
        if rebuilt:                         # bytes matrix / adjacency changed
            self._comm_cache.clear()
            self._engine = None             # structure changed: tables stale
            self._dist = None               # wire layout / spans changed too
        else:
            if self._engine is not None:
                self._engine.refresh_payload(self._geo, use_pending=use_dev)
            if self._dist is not None:
                # dist recomputes multipoles AND LET wire payloads on device
                # from the restacked (x, q) — no host LET refresh needed
                self._dist.refresh_payload(self._geo)
        return report

    @staticmethod
    def _advance(geo: GeometryPlan, new_x, new_q, delta,
                 rebuilt: set, refreshed: set,
                 defer_numeric: bool = False) -> GeometryPlan:
        spec = geo.spec
        from repro.core.engine.traversal import resolve_traversal_backend
        backend = resolve_traversal_backend(spec.traversal_backend)
        P = spec.nparts
        ops = get_operators(spec.p)
        touched = rebuilt | refreshed
        if rebuilt:
            # LET re-extraction below reads refreshed senders' host
            # multipoles: fill any deferred mirrors first
            sync_host_multipoles(geo)
        trees, scheds, Ms = list(geo.trees), list(geo.scheds), list(geo.Ms)
        boxes, adj_boxes = geo.boxes.copy(), geo.adj_boxes.copy()
        lets, B = dict(geo.lets), geo.bytes_matrix.copy()
        x_ref = geo.x_ref.copy()

        # 1. rebuild invalidated partitions' local structure from scratch
        for j in rebuilt:
            idx = geo.owners[j]
            t = build_tree(new_x[idx], new_q[idx], ncrit=spec.ncrit)
            trees[j], scheds[j] = t, build_tree_schedules(t)
            Ms[j] = np.asarray(upward_pass(t, ops, sched=scheds[j]))
            boxes[j, 0] = new_x[idx].min(axis=0)
            boxes[j, 1] = new_x[idx].max(axis=0)
            # union-expand the adjacency box: Lemma-1 neighbor sets only grow,
            # so cached HSDX reachability stays conservative
            adj_boxes[j, 0] = np.minimum(adj_boxes[j, 0], boxes[j, 0])
            adj_boxes[j, 1] = np.maximum(adj_boxes[j, 1], boxes[j, 1])
            x_ref[idx] = new_x[idx]

        # 2. drift within slack: same structure, rebound coordinates/charges
        #    and recomputed multipoles about the build-time expansion centers
        #    (multipole recompute deferred to the device engine when it owns
        #    evaluation — sync_host_multipoles fills the NumPy mirror lazily)
        for j in refreshed:
            idx = geo.owners[j]
            t = trees[j]
            t = dc_replace(t, x=new_x[idx][t.perm], q=new_q[idx][t.perm])
            trees[j] = t
            if not defer_numeric:
                Ms[j] = np.asarray(upward_pass(t, ops, sched=scheds[j]))

        # 3. LETs: re-extract a pair iff either end was rebuilt; rebind the
        #    payload iff only the sender drifted within slack
        for i in range(P):
            if trees[i] is None:
                continue
            targets = [j for j in range(P) if j != i and trees[j] is not None
                       and (i in rebuilt or j in rebuilt)]
            if targets:
                tj = np.asarray(targets)
                lo, hi = boxes[tj, 0].copy(), boxes[tj, 1].copy()
                # a valid-but-drifted receiver can poke past its build-time
                # tight box by at most its drift — extract conservatively
                pad = np.array([delta[j] if j not in rebuilt else 0.0
                                for j in targets])
                lo -= pad[:, None]
                hi += pad[:, None]
                for j, let in zip(targets, extract_lets(trees[i], Ms[i],
                                                        lo, hi, spec.theta)):
                    lets[(i, j)] = let
                    B[i, j] = let.nbytes
            if i in refreshed and not defer_numeric:
                # rebuilt senders were re-extracted above
                for j in range(P):
                    if j != i and (i, j) in lets and j not in rebuilt:
                        lets[(i, j)] = refresh_let(lets[(i, j)], trees[i],
                                                   Ms[i])

        # 4. receiver plans: re-traverse a pair iff either end was rebuilt;
        #    re-graft (cheap view) iff its LET payload was rebound (deferred
        #    with the payload itself under engine dispatch)
        receivers = list(geo.receivers)
        pad_cells = _geometry_pad_cells(trees) if rebuilt else None
        for j in range(P) if not defer_numeric else ():
            if trees[j] is None:
                continue
            r = receivers[j]
            senders = [i for i in range(P) if (i, j) in lets]
            if j not in touched and not any(i in touched for i in senders):
                continue
            old = {rb.sender: rb for rb in r.remote}
            remote = []
            for i in senders:
                if i in rebuilt or j in rebuilt:
                    remote.append(_remote_block(i, lets[(i, j)], trees[j],
                                                spec.theta, backend,
                                                pad_cells))
                elif i in touched:
                    remote.append(_rebind_remote(old[i], lets[(i, j)]))
                else:
                    remote.append(old[i])
            if j in rebuilt:
                local, lm = _plan_pair(trees[j], trees[j], spec.theta, False,
                                       backend, pad_cells)
            else:
                local, lm = r.local, r.local_margin
            receivers[j] = ReceiverPlan(tree=trees[j], sched=scheds[j],
                                        local=local, local_margin=lm,
                                        remote=remote)

        if rebuilt:
            adj = adjacency_from_boxes(adj_boxes)
            deg = float(np.max([len(a) for a in adj]))
            diam = graph_diameter(adj)
            slack = _slack_budget(P, spec.theta, receivers, lets)
        else:
            deg, diam, slack = geo.adjacency_degree, geo.diameter, geo.slack

        # deferred-mirror bookkeeping: rebuilds synced everything up front;
        # otherwise carry prior stale partitions (minus any recomputed now)
        prior = set() if rebuilt else set(geo.Ms_stale)
        stale = tuple(sorted((prior | refreshed) if defer_numeric
                             else (prior - refreshed)))
        return GeometryPlan(
            spec=spec, n=geo.n, x0=new_x, q0=new_q, x_ref=x_ref,
            part=geo.part, owners=geo.owners, boxes=boxes,
            adj_boxes=adj_boxes, trees=trees, scheds=scheds, Ms=Ms, lets=lets,
            receivers=receivers, bytes_matrix=B, adjacency_degree=deg,
            diameter=diam, slack=slack,
            partition_stats=geo.partition_stats, version=geo.version + 1,
            Ms_stale=stale)
