"""Dual-tree traversal with the flexible multipole acceptance criterion.

MAC (exaFMM convention): a cell pair (A, B) is *well separated* iff
    R_A + R_B < theta * |c_A - c_B|
with *tight* radii/centers (squeezed bounding boxes).  The flexible MAC is
what lets the hybrid-ORB scheme tolerate misaligned local trees (paper §2.2).

The traversal is *frontier-vectorized*: instead of a per-pair Python stack it
keeps a (K, 2) array of undecided (target, source) cell pairs and advances the
whole frontier at once — one vectorized MAC test, one vectorized
leaf/truncation classification, and child expansion via the
`np.repeat`/`np.cumsum` segmented-arange idiom.  The only Python loop is over
frontier generations (O(tree depth) iterations), never over pairs or cells.

The seed's per-pair stack version is retained as
`repro.core.reference.reference_dual_traversal` and the two are pinned to
produce identical pair *sets* by golden tests (ordering differs: stack vs
generation order).

This module is now the **host reference** tier of a two-backend traversal:
`repro.core.engine.traversal.device_dual_traversal` runs the same frontier
loop as a single `jax.lax.while_loop` device program (Pallas MAC scoring,
exact host emission order) and is the default wherever an accelerator
backend is present (`PartitionSpec(traversal_backend=...)`).  This f64
NumPy loop stays authoritative: it is the precision anchor the f32 device
decisions are golden-tested against (byte-identical pair lists on
MAC-robust inputs — tests/test_traversal_device*.py), the CPU default, and
the fallback when no accelerator exists.

Host-side NumPy; outputs are flat pair lists consumed by the JAX evaluator.
"""
from __future__ import annotations

import numpy as np

from repro.core.tree import _segmented_arange

__all__ = ["dual_traversal", "mac_ok"]


def mac_ok(ca, ra, cb, rb, theta: float) -> bool:
    d = float(np.linalg.norm(ca - cb))
    return (ra + rb) < theta * d


def dual_traversal(tgt_tree, src_tree, theta: float = 0.5, with_m2p: bool = False):
    """Returns (m2l_pairs, p2p_pairs[, m2p_pairs]) as (*,2) int arrays of
    (target_cell, source_cell).

    If the source tree is a grafted LET, some source cells are *truncated*:
    multipole-sufficient leaves with no children and no bodies (see let.py).
    A truncated cell that fails the MAC against a local *leaf* falls back to
    M2P (direct multipole evaluation at the leaf's bodies), which is accurate
    because the sender's acceptance criterion 2 R_c < theta * dist(c, box)
    bounds R_c / |y - c| < theta/2 for every body y in the remote box.
    """
    tc, tr = tgt_tree.center, tgt_tree.radius
    sc, sr = src_tree.center, src_tree.radius
    t_leaf = np.asarray(tgt_tree.is_leaf)
    s_leaf = np.asarray(src_tree.is_leaf)
    truncated = getattr(src_tree, "truncated", None)
    if truncated is None:
        truncated = np.zeros(len(sc), dtype=bool)
    t_cs, t_nc = tgt_tree.child_start, tgt_tree.n_child
    s_cs, s_nc = src_tree.child_start, src_tree.n_child

    m2l_ch, p2p_ch, m2p_ch = [], [], []
    A = np.zeros(1, dtype=np.int64)
    B = np.zeros(1, dtype=np.int64)
    while len(A):
        d = np.linalg.norm(tc[A] - sc[B], axis=1)
        far = (tr[A] + sr[B]) < theta * d
        if far.any():
            m2l_ch.append(np.stack([A[far], B[far]], axis=1))
            A, B = A[~far], B[~far]
        both_leaf = t_leaf[A] & s_leaf[B]
        if both_leaf.any():
            tb = both_leaf & truncated[B]
            pb = both_leaf & ~tb
            if tb.any():
                m2p_ch.append(np.stack([A[tb], B[tb]], axis=1))
            if pb.any():
                p2p_ch.append(np.stack([A[pb], B[pb]], axis=1))
            A, B = A[~both_leaf], B[~both_leaf]
        if not len(A):
            break
        # split the larger cell (or the only splittable one)
        split_t = (~t_leaf[A]) & (s_leaf[B] | (tr[A] >= sr[B]))
        At, Bt = A[split_t], B[split_t]
        As, Bs = A[~split_t], B[~split_t]
        nt = t_nc[At]
        rep_t = np.repeat(np.arange(len(At)), nt)
        child_t = t_cs[At][rep_t] + _segmented_arange(nt)
        ns = s_nc[Bs]
        rep_s = np.repeat(np.arange(len(Bs)), ns)
        child_s = s_cs[Bs][rep_s] + _segmented_arange(ns)
        A = np.concatenate([child_t, As[rep_s]])
        B = np.concatenate([Bt[rep_t], child_s])

    def _cat(chunks):
        if not chunks:
            return np.zeros((0, 2), dtype=np.int64)
        return np.concatenate(chunks, axis=0)

    m2l, p2p, m2p = _cat(m2l_ch), _cat(p2p_ch), _cat(m2p_ch)
    if with_m2p:
        return m2l, p2p, m2p
    assert len(m2p) == 0, "truncated source cells require with_m2p=True"
    return m2l, p2p
