"""Dual-tree traversal with the flexible multipole acceptance criterion.

MAC (exaFMM convention): a cell pair (A, B) is *well separated* iff
    R_A + R_B < theta * |c_A - c_B|
with *tight* radii/centers (squeezed bounding boxes).  The flexible MAC is
what lets the hybrid-ORB scheme tolerate misaligned local trees (paper §2.2).

Host-side NumPy; outputs are flat pair lists consumed by the JAX evaluator.
"""
from __future__ import annotations

import numpy as np

__all__ = ["dual_traversal", "mac_ok"]


def mac_ok(ca, ra, cb, rb, theta: float) -> bool:
    d = float(np.linalg.norm(ca - cb))
    return (ra + rb) < theta * d


def dual_traversal(tgt_tree, src_tree, theta: float = 0.5, with_m2p: bool = False):
    """Returns (m2l_pairs, p2p_pairs[, m2p_pairs]) as (*,2) int arrays of
    (target_cell, source_cell).

    If the source tree is a grafted LET, some source cells are *truncated*:
    multipole-sufficient leaves with no children and no bodies (see let.py).
    A truncated cell that fails the MAC against a local *leaf* falls back to
    M2P (direct multipole evaluation at the leaf's bodies), which is accurate
    because the sender's acceptance criterion 2 R_c < theta * dist(c, box)
    bounds R_c / |y - c| < theta/2 for every body y in the remote box.
    """
    m2l, p2p, m2p = [], [], []
    tc, tr = tgt_tree.center, tgt_tree.radius
    sc, sr = src_tree.center, src_tree.radius
    t_leaf, s_leaf = tgt_tree.is_leaf, src_tree.is_leaf
    truncated = getattr(src_tree, "truncated", None)
    if truncated is None:
        truncated = np.zeros(len(sc), dtype=bool)
    stack = [(0, 0)]
    while stack:
        a, b = stack.pop()
        d = np.linalg.norm(tc[a] - sc[b])
        if (tr[a] + sr[b]) < theta * d:
            m2l.append((a, b))
            continue
        if t_leaf[a] and s_leaf[b]:
            if truncated[b]:
                m2p.append((a, b))
            else:
                p2p.append((a, b))
            continue
        # split the larger cell (or the only splittable one)
        split_target = (not t_leaf[a]) and (s_leaf[b] or tr[a] >= sr[b])
        if split_target:
            cs, nc = tgt_tree.child_start[a], tgt_tree.n_child[a]
            for c in range(cs, cs + nc):
                stack.append((c, b))
        else:
            cs, nc = src_tree.child_start[b], src_tree.n_child[b]
            for c in range(cs, cs + nc):
                stack.append((a, c))
    m2l = np.asarray(m2l, dtype=np.int64).reshape(-1, 2)
    p2p = np.asarray(p2p, dtype=np.int64).reshape(-1, 2)
    m2p = np.asarray(m2p, dtype=np.int64).reshape(-1, 2)
    if with_m2p:
        return m2l, p2p, m2p
    assert len(m2p) == 0, "truncated source cells require with_m2p=True"
    return m2l, p2p
