"""HSDX — hierarchical sparse data exchange (paper §4.2, Algorithm 1).

Communication happens strictly between *spatially adjacent* partitions
(Lemma 1: bounding boxes sharing a face/edge/vertex within eps).  For every
target process a breadth-first comm tree is built over the adjacency graph
(BuildCommTree); payloads for non-neighbors are relayed hop by hop, one
`MPI_Neighbor_alltoallv`-style aggregated exchange per stage.  Edges are
"hardwired" so relay load spreads evenly over direct neighbors — the uniform-
grid balance bound is Eq (1):  NB = ceil((5^D - 3^D) / (3^D - 1)).

Round/byte accounting (single source of truth with the real exchange)
---------------------------------------------------------------------
A `protocols.Schedule` *stage* is a sparse set of directed transfers; a
device collective moves one buffer per rank per op, so a stage executes as
one or more *rounds*, each a partial permutation of ranks (every rank sends
at most once and receives at most once — exactly one `jax.lax.ppermute`).
`decompose_rounds` is that decomposition, and it is shared verbatim by the
modeled accounting (`protocols.schedule_stats`'s `n_rounds`) and the real
multi-device exchange programs (`repro.core.dist.programs`), so the rounds
the LogGP model charges for are the rounds the wire actually executes —
tests assert the modeled per-edge bytes equal the bytes the programs move.
"""
from __future__ import annotations

from collections import deque

import numpy as np

__all__ = ["adjacency_from_boxes", "nb_bound", "build_comm_tree",
           "relay_routes", "graph_diameter", "decompose_rounds"]


def nb_bound(D: int = 3) -> int:
    """Eq (1) for a uniform D-dim grid: avg messages received per neighbor
    per stage under balanced hardwiring."""
    return int(np.ceil((5 ** D - 3 ** D) / (3 ** D - 1)))


def adjacency_from_boxes(boxes: np.ndarray, eps: float = 1e-9) -> list[list[int]]:
    """Lemma 1: P' is adjacent to P iff their boxes overlap within eps in
    every dimension (face/edge/vertex sharing).  boxes: (P, 2, 3).

    A partition with no bodies carries the empty-box sentinel (lo > hi, i.e.
    lo=+inf / hi=-inf) and is adjacent to nothing — it neither sends nor
    receives LET payloads, so routing must never relay through it."""
    P = len(boxes)
    adj = [[] for _ in range(P)]
    empty = np.any(boxes[:, 1] < boxes[:, 0], axis=1)
    for i in range(P):
        if empty[i]:
            continue
        for j in range(i + 1, P):
            if empty[j]:
                continue
            lo = np.maximum(boxes[i, 0], boxes[j, 0])
            hi = np.minimum(boxes[i, 1], boxes[j, 1])
            if np.all(hi - lo >= -eps):
                adj[i].append(j)
                adj[j].append(i)
    return adj


def build_comm_tree(adj: list[list[int]], root: int) -> np.ndarray:
    """BFS tree toward `root` with *balanced* parent selection: among the
    candidate parents (BFS-level-below neighbors), pick the least-loaded one,
    so relay traffic spreads per Eq (1).  Returns parent[] (root's = -1)."""
    P = len(adj)
    level = np.full(P, -1, dtype=np.int64)
    parent = np.full(P, -1, dtype=np.int64)
    load = np.zeros(P, dtype=np.int64)
    level[root] = 0
    q = deque([root])
    order = []
    while q:
        u = q.popleft()
        order.append(u)
        for v in adj[u]:
            if level[v] < 0:
                level[v] = level[u] + 1
                q.append(v)
    # assign parents by increasing level; balanced choice among candidates
    for v in sorted(range(P), key=lambda v: level[v]):
        if v == root or level[v] < 0:
            continue
        cands = [u for u in adj[v] if level[u] == level[v] - 1]
        u = min(cands, key=lambda u: (load[u], u))
        parent[v] = u
        load[u] += 1
    return parent


def relay_routes(adj: list[list[int]]) -> dict[tuple[int, int], list[int]]:
    """Hop sequences: routes[(src, dst)] = [src, r1, ..., dst] along the
    balanced BFS tree rooted at each destination."""
    P = len(adj)
    routes: dict[tuple[int, int], list[int]] = {}
    for dst in range(P):
        parent = build_comm_tree(adj, dst)
        for src in range(P):
            if src == dst:
                continue
            path = [src]
            u = src
            while u != dst:
                u = int(parent[u])
                if u < 0:  # disconnected graph — direct fallback
                    path = [src, dst]
                    break
                path.append(u)
            routes[(src, dst)] = path
    return routes


def decompose_rounds(
    edges: list[tuple[int, int]],
) -> list[list[tuple[int, int]]]:
    """Partition a directed edge set into *rounds*, each a partial
    permutation: within a round every rank sends at most once and receives
    at most once, so a round maps onto exactly one `jax.lax.ppermute`.

    Greedy first-fit over the (deduplicated, sorted) edge list.  The result
    is deterministic, covers every edge exactly once, and is what both the
    modeled accounting (`protocols.schedule_stats` `n_rounds`) and the real
    exchange programs (`repro.core.dist.programs`) execute — one source of
    truth for "how many collectives does this stage cost".
    """
    remaining = sorted(set((int(u), int(v)) for (u, v) in edges))
    if any(u == v for (u, v) in remaining):
        raise ValueError("self-edge in round decomposition")
    rounds: list[list[tuple[int, int]]] = []
    while remaining:
        srcs: set[int] = set()
        dsts: set[int] = set()
        rnd: list[tuple[int, int]] = []
        rest: list[tuple[int, int]] = []
        for (u, v) in remaining:
            if u not in srcs and v not in dsts:
                rnd.append((u, v))
                srcs.add(u)
                dsts.add(v)
            else:
                rest.append((u, v))
        rounds.append(rnd)
        remaining = rest
    return rounds


def graph_diameter(adj: list[list[int]]) -> int:
    P = len(adj)
    diam = 0
    for s in range(P):
        dist = np.full(P, -1)
        dist[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for v in adj[u]:
                if dist[v] < 0:
                    dist[v] = dist[u] + 1
                    q.append(v)
        diam = max(diam, int(dist.max()))
    return diam
