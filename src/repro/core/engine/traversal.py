"""Device-resident dual-tree traversal and step revalidation.

The host traversal (repro.core.traversal.dual_traversal) is already
frontier-vectorized, but every generation is a NumPy pass with a host
round-trip per level — the dominant `plan_geometry` cost ahead of the PR 3
device engine.  The frontier arrays are pure flat index math (Hu, Gumerov &
Duraiswami's observation that FMM data-structure construction is itself
data-parallel), so this module runs the whole loop as ONE device program:

  - state is a padded `(pair_frontier, count)` tuple driven by
    `jax.lax.while_loop` — no host round-trip between generations;
  - the MAC score `theta*d - (Ra+Rb)` for a whole frontier is one Pallas
    launch (repro.kernels.mac), jnp reference where Pallas would interpret;
  - accepted / leaf-leaf / truncated pairs append to padded output buffers
    via mask + exclusive-cumsum scatters (mode="drop" keeps shapes static);
  - child expansion replicates the host ordering exactly (target-split
    children first, then source-split), so the emitted pair lists are
    *byte-identical in order* to `dual_traversal` whenever the f32 MAC
    decisions agree with the f64 host decisions — which the golden tests pin
    on robust cases (see tests/test_traversal_device.py).

Capacities are static powers of two derived from the padded cell count; an
overflow flag triggers a doubled-capacity retry on the host (rare — the
heuristics overshoot).  All trees of one geometry share one padded cell
envelope, so every (receiver, sender) pair of a `plan_geometry` reuses a
single traced program.

The traversal also returns the minimum accepted-M2L margin — exactly the
slack quantity `api._m2l_margin` recomputes on the host — so a device-planned
geometry's MAC-slack budgets consume device margins directly.

Step revalidation (`partition_drift` / `restack_payload`): a within-slack
`FMMSession.step` needs per-partition `max |x_new - x_ref|` drift and a
changed-partition mask.  Instead of the per-partition NumPy loop, the engine
uploads `new_x` once, restacks it into the `(P, Nmax, 3)` payload envelope
through the frozen global-id gather tables ON DEVICE, and reduces drift for
all partitions in one batched launch — the restacked payload then *is* the
next evaluation's payload, so a within-slack step transfers exactly one
`(N, 3)` array host->device and `(P,)` scalars back.
"""
from __future__ import annotations

import functools
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import flat_cell_tables
from repro.kernels.mac import mac_margins, mac_margins_ref

__all__ = ["device_dual_traversal", "default_traversal_backend",
           "resolve_traversal_backend", "partition_drift", "restack_payload",
           "traversal_caps"]

_TABLE_KEYS = ("center", "radius", "child_start", "n_child", "is_leaf",
               "truncated")


def default_traversal_backend() -> str:
    """Mirror the engine dispatch default: frontier math on the accelerator
    wherever one is present; the NumPy reference stays the CPU default so CPU
    test runs pin it byte-identically."""
    return "host" if jax.default_backend() in ("cpu",) else "device"


def resolve_traversal_backend(backend: str | None) -> str:
    b = default_traversal_backend() if backend in (None, "auto") else backend
    if b not in ("host", "device"):
        raise ValueError(f"traversal_backend must be 'host', 'device' or "
                         f"'auto', got {backend!r}")
    return b


def default_use_mac_kernel() -> bool:
    from repro.kernels import ops
    return not ops.INTERPRET


# measured ratios vs cell count (sphere/plummer/cube, theta=0.5): frontier
# peaks at 26-212x cells, m2l totals 49-256x, p2p 10-128x, m2p tiny.  Start
# from the mid-range multipliers below and remember overflow-doubled caps per
# padded-cell class, so one geometry pays at most one wasted partial run.
_CAP_MULT = (32, 64, 32, 2)      # frontier, m2l, p2p, m2p
_CAPS_CACHE: dict[int, tuple] = {}


def traversal_caps(pad_cells: int) -> tuple:
    """(frontier, m2l, p2p, m2p) capacities — powers of two (multiples of the
    MAC kernel's 128-lane tile) shared by every pair of one geometry.  Serves
    the last overflow-doubled choice for this padded-cell class when one is
    cached."""
    hit = _CAPS_CACHE.get(int(pad_cells))
    if hit is not None:
        return hit
    def cap(k):
        return max(128, 1 << int(np.ceil(np.log2(max(k, 1)))))
    return tuple(cap(m * pad_cells) for m in _CAP_MULT)


# --------------------------------------------------------- traced program ---
@functools.partial(jax.jit,
                   static_argnames=("theta", "caps", "use_kernel", "interpret"))
def _traversal_loop(tt, ts, *, theta, caps, use_kernel, interpret):
    """One device program: the whole dual traversal of one (target, source)
    tree pair.  tt/ts: flat cell tables (tree.flat_cell_tables, uploaded).
    Returns padded output buffers + counts + min accepted margin + overflow.
    """
    Kcap, Mcap, Pcap, Qcap = caps
    i32 = jnp.int32

    def score(ca, ra, cb, rb):
        if use_kernel:
            return mac_margins(ca, ra, cb, rb, theta, interpret=interpret)
        return mac_margins_ref(ca, ra, cb, rb, theta)

    def append(mask, A, B, out_a, out_b, count, cap):
        m = mask.astype(i32)
        pos = count + jnp.cumsum(m) - m              # exclusive prefix
        idx = jnp.where(mask, pos, cap)              # cap => dropped
        return (out_a.at[idx].set(A, mode="drop"),
                out_b.at[idx].set(B, mode="drop"),
                count + m.sum())

    def body(st):
        A, B, n = st["A"], st["B"], st["n"]
        valid = jnp.arange(Kcap, dtype=i32) < n
        ca, ra = tt["center"][A], tt["radius"][A]
        cb, rb = ts["center"][B], ts["radius"][B]
        margin = score(ca, ra, cb, rb)
        far = valid & (margin > 0)
        min_margin = jnp.minimum(
            st["min_margin"], jnp.min(jnp.where(far, margin, jnp.inf)))
        leaf_t, leaf_s = tt["is_leaf"][A], ts["is_leaf"][B]
        both_leaf = valid & ~far & leaf_t & leaf_s
        trunc = both_leaf & ts["truncated"][B]
        near = both_leaf & ~trunc

        m2l_a, m2l_b, n_m2l = append(far, A, B, st["m2l_a"], st["m2l_b"],
                                     st["n_m2l"], Mcap)
        p2p_a, p2p_b, n_p2p = append(near, A, B, st["p2p_a"], st["p2p_b"],
                                     st["n_p2p"], Pcap)
        m2p_a, m2p_b, n_m2p = append(trunc, A, B, st["m2p_a"], st["m2p_b"],
                                     st["n_m2p"], Qcap)

        # split the larger cell (or the only splittable one) — host rule,
        # host ordering: target-split children first, then source-split
        rem = valid & ~far & ~both_leaf
        split_t = rem & ~leaf_t & (leaf_s | (ra >= rb))
        split_s = rem & ~split_t
        nt = jnp.where(split_t, tt["n_child"][A], 0).astype(i32)
        ns = jnp.where(split_s, ts["n_child"][B], 0).astype(i32)
        off_t = jnp.cumsum(nt) - nt
        total_t = nt.sum()
        off_s = total_t + jnp.cumsum(ns) - ns
        new_n = total_t + ns.sum()

        col = jnp.arange(8, dtype=i32)[None, :]      # octree: <= 8 children
        newA = jnp.zeros(Kcap, i32)
        newB = jnp.zeros(Kcap, i32)
        tpos = jnp.where(col < nt[:, None], off_t[:, None] + col, Kcap)
        newA = newA.at[tpos.ravel()].set(
            (tt["child_start"][A][:, None] + col).ravel(), mode="drop")
        newB = newB.at[tpos.ravel()].set(
            jnp.broadcast_to(B[:, None], (Kcap, 8)).ravel(), mode="drop")
        spos = jnp.where(col < ns[:, None], off_s[:, None] + col, Kcap)
        newA = newA.at[spos.ravel()].set(
            jnp.broadcast_to(A[:, None], (Kcap, 8)).ravel(), mode="drop")
        newB = newB.at[spos.ravel()].set(
            (ts["child_start"][B][:, None] + col).ravel(), mode="drop")

        overflow = (st["overflow"] | (n_m2l > Mcap) | (n_p2p > Pcap)
                    | (n_m2p > Qcap) | (new_n > Kcap))
        return {"A": newA, "B": newB, "n": new_n,
                "m2l_a": m2l_a, "m2l_b": m2l_b, "n_m2l": n_m2l,
                "p2p_a": p2p_a, "p2p_b": p2p_b, "n_p2p": n_p2p,
                "m2p_a": m2p_a, "m2p_b": m2p_b, "n_m2p": n_m2p,
                "min_margin": min_margin, "overflow": overflow}

    init = {"A": jnp.zeros(Kcap, i32), "B": jnp.zeros(Kcap, i32),
            "n": jnp.asarray(1, i32),
            "m2l_a": jnp.zeros(Mcap, i32), "m2l_b": jnp.zeros(Mcap, i32),
            "n_m2l": jnp.asarray(0, i32),
            "p2p_a": jnp.zeros(Pcap, i32), "p2p_b": jnp.zeros(Pcap, i32),
            "n_p2p": jnp.asarray(0, i32),
            "m2p_a": jnp.zeros(Qcap, i32), "m2p_b": jnp.zeros(Qcap, i32),
            "n_m2p": jnp.asarray(0, i32),
            "min_margin": jnp.asarray(jnp.inf, jnp.float32),
            "overflow": jnp.asarray(False)}
    return jax.lax.while_loop(
        lambda st: (st["n"] > 0) & ~st["overflow"], body, init)


# ----------------------------------------------------------- host wrapper ---
def _as_device_tables(tables: dict) -> dict:
    return {k: jnp.asarray(tables[k]) for k in _TABLE_KEYS}


# (id(tree), pad_cells) -> (weakref anchor, device tables).  plan_geometry
# traverses every receiver tree against P-1 senders plus itself; without this
# memo each pair would rebuild + re-upload the same flat tables.  Entries
# self-evict when the tree dies (same pattern as api.DeviceMemo).  Grafted
# LET views are deliberately NOT memoized: each graft is traversed exactly
# once but lives in its RemoteBlock for the geometry's lifetime, so caching
# would pin O(P^2 * pad_cells) device tables with zero reuse.
_TREE_TABLE_CACHE: dict = {}


def _device_tables_for(tree, pad_cells: int | None) -> dict:
    if getattr(tree, "truncated", None) is not None:    # grafted LET view
        return _as_device_tables(flat_cell_tables(tree, pad_cells=pad_cells))
    key = (id(tree), pad_cells)
    hit = _TREE_TABLE_CACHE.get(key)
    if hit is not None:
        return hit[1]
    dev = _as_device_tables(flat_cell_tables(tree, pad_cells=pad_cells))
    try:
        anchor = weakref.ref(tree,
                             lambda _, k=key: _TREE_TABLE_CACHE.pop(k, None))
    except TypeError:
        anchor = tree
    _TREE_TABLE_CACHE[key] = (anchor, dev)
    return dev


def device_dual_traversal(tgt_tree, src_tree, theta: float = 0.5,
                          with_m2p: bool = False, *, pad_cells: int | None = None,
                          use_kernel: bool | None = None,
                          interpret: bool | None = None,
                          max_retries: int = 8):
    """Device dual traversal of one (target, source) tree pair.

    Returns `(m2l, p2p, m2p, min_margin)`: `(*, 2)` int64 host pair arrays in
    the exact emission order of the host reference, plus the minimum accepted
    M2L margin `theta*d - (Ra+Rb)` (f32; +inf when no pair was accepted).
    With `with_m2p=False`, truncated source cells are a contract violation
    (same assert as the host path).  Overflowing a capacity retries with all
    capacities doubled (`max_retries` guards runaways).
    """
    if use_kernel is None:
        use_kernel = default_use_mac_kernel()
    if interpret is None:
        from repro.kernels import ops
        interpret = ops.INTERPRET
    tt = _device_tables_for(tgt_tree, pad_cells)
    ts = tt if src_tree is tgt_tree else _device_tables_for(src_tree,
                                                           pad_cells)
    pad_class = max(tt["radius"].shape[0], ts["radius"].shape[0])
    caps = traversal_caps(pad_class)
    grew = False
    for _ in range(max_retries + 1):
        out = _traversal_loop(tt, ts, theta=float(theta), caps=caps,
                              use_kernel=bool(use_kernel),
                              interpret=bool(interpret))
        if not bool(out["overflow"]):
            if grew:        # remember only capacities that actually worked
                _CAPS_CACHE[int(pad_class)] = caps
            break
        caps = tuple(2 * c for c in caps)
        grew = True
    else:
        raise RuntimeError(f"device traversal overflowed after "
                           f"{max_retries} capacity doublings")

    def pairs(a, b, n):
        n = int(n)
        return np.stack([np.asarray(a[:n], np.int64),
                         np.asarray(b[:n], np.int64)], axis=1)

    m2l = pairs(out["m2l_a"], out["m2l_b"], out["n_m2l"])
    p2p = pairs(out["p2p_a"], out["p2p_b"], out["n_p2p"])
    m2p = pairs(out["m2p_a"], out["m2p_b"], out["n_m2p"])
    if not with_m2p and len(m2p):
        raise AssertionError("truncated source cells require with_m2p=True")
    return m2l, p2p, m2p, float(out["min_margin"])


# ------------------------------------------------------ step revalidation ---
@functools.partial(jax.jit, static_argnames=("shape",))
def _restack_kernel(new, orig_idx, flat_idx, *, shape):
    P, Nmax = shape
    tail = new.shape[1:]
    flat = jnp.zeros((P * Nmax,) + tail, jnp.float32)
    return flat.at[flat_idx].set(new[orig_idx]).reshape((P, Nmax) + tail)


def restack_payload(new, orig_idx, flat_idx, n_parts: int, n_bodies_max: int):
    """Scatter an original-order device array (N, ...) into the engine's
    stacked `(P, Nmax, ...)` payload envelope — the device-side equivalent of
    `schedules.stack_bodies`, consuming the uploaded `new_x` directly (no
    host restack, no per-partition transfers)."""
    return _restack_kernel(new, orig_idx, flat_idx,
                           shape=(int(n_parts), int(n_bodies_max)))


@jax.jit
def _drift_changed_kernel(x_pad, ref_pad, old_pad):
    drift = jnp.sqrt(((x_pad - ref_pad) ** 2).sum(-1).max(1))
    changed = jnp.abs(x_pad - old_pad).max(axis=(1, 2)) > 0
    return drift, changed


def partition_drift(x_pad, ref_pad, old_pad):
    """Batched MAC-slack revalidation inputs: per-partition drift
    `max_i |x_i - x_ref_i|` against the structure reference and a
    changed-since-last-payload mask — ONE launch for all partitions (the
    host path loops partitions in NumPy).  Padded rows are zero in all three
    arrays and contribute drift 0 / changed False."""
    return _drift_changed_kernel(x_pad, ref_pad, old_pad)
