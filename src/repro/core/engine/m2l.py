"""Batched far field: one segment-summed M2L over every (receiver, sender)
pair, then a vmapped downward sweep and leaf evaluation.

The reference path scatters one M2L launch per interaction plan (local plus
one per remote block, per receiver) and walks each receiver's L2L levels in
its own Python loop.  The engine flattens all of it:

  - M2L: every plan's valid pair rows are concatenated — receiver-major,
    local block first then senders ascending, matching the reference
    accumulation order — with *global* cell ids (`p * n_cells_max + c`), and
    applied as ONE `ops.m2l_v` + segment-sum scatter into the flat local
    array.  Grafted-LET sources were translated to sender-global ids at
    table-build time, so remote M2L reads the sender's device multipoles
    directly: no LET payload ever crosses the host boundary.
  - Downward/L2P: top-aligned stacked level tables, one vmapped L2L scatter
    per level slot, then one vmapped leaf evaluation producing the padded
    value tables the host accumulates in float64.
  - M2P fallback rows (truncated remote cells vs large local leaves) batch
    the same way against the flat multipole array.

Values return as padded f32 tables; the final float64 accumulation happens
once on the host (matching the reference executors' precision exactly).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["far_tail_kernel", "m2p_vals_kernel"]


@partial(jax.jit, static_argnums=(0,))
def far_tail_kernel(ops, M, x, m2l, down_ids, down_parents, down_mask,
                    down_d, leaves, leaf_mask, leaf_centers, leaf_idx):
    """M (P,C,nk), x (P,N,3) + tables -> padded L2P values (P, Bl, W)."""
    P, C, nk = M.shape
    M_flat = M.reshape(P * C, nk)
    L_flat = jnp.zeros_like(M_flat)
    if m2l["src"].shape[0]:
        contrib = ops.m2l_v(M_flat[m2l["src"]], m2l["d"]) * m2l["mask"][:, None]
        L_flat = L_flat.at[m2l["tgt"]].add(contrib)
    L = L_flat.reshape(P, C, nk)

    def l2l_one(Lp, ids, parents, mask, d):
        contrib = ops.l2l_v(Lp[parents], d) * mask[:, None]
        return Lp.at[ids].add(contrib)

    for lvl in range(down_ids.shape[1]):         # slot 0 = level 1 (top)
        L = jax.vmap(l2l_one)(L, down_ids[:, lvl], down_parents[:, lvl],
                              down_mask[:, lvl], down_d[:, lvl])

    def l2p_one(Lp, xp, lf, lm, lc, li):
        return ops.l2p_v(Lp[lf], xp[li], lc) * lm[:, None]

    return jax.vmap(l2p_one)(L, x, leaves, leaf_mask, leaf_centers, leaf_idx)


@partial(jax.jit, static_argnums=(0,))
def m2p_vals_kernel(ops, M, x, b, centers, mask, t_idx):
    """Batched M2P fallback values (B, wt) against flat global multipoles."""
    P, C, nk = M.shape
    M_flat = M.reshape(P * C, nk)
    x_flat = x.reshape(-1, 3)
    return ops.m2p_v(M_flat[b], x_flat[t_idx], centers) * mask[:, None]
