"""Device evaluation engine: batched multi-tree FMM execution.

Fourth tier of the pipeline — `plan_geometry` (host geometry) ->
`schedule_comm` (protocol schedules) -> **`DeviceEngine`** (batched device
execution) -> `FMMSession` (orchestration).  A `DeviceEngine` is compiled
once per `GeometryPlan`: `schedules.build_engine_tables` stacks every
partition's frozen per-tree tables into `(n_parts, ...)` envelopes, and
evaluation then runs

  1. one batched upward launch (`upward.batched_upward_kernel`) — P2M + M2M
     for ALL partitions, replacing the per-partition Python sweep;
  2. one far-field launch (`m2l.far_tail_kernel`) — a segment-summed M2L
     over every (receiver, sender) pair reading sender-global device
     multipoles (grafted LETs never materialize on the host), the stacked
     downward sweep, and the leaf evaluation;
  3. one launch per P2P width-class bucket (`p2p.p2p_bucket_vals`),
     Pallas-backed with per-(S, n_pairs) autotuned block sizes on device
     backends, jnp reference on CPU; plus one batched M2P fallback launch.

Float64 accumulation of the f32 value tables happens once on the host, at
the API boundary — identical precision to the reference executors, which is
what pins the engine allclose to `api.execute_geometry`.

Timesteps: index tables are payload-independent, so a within-slack
`FMMSession.step` calls `refresh_payload` — restack + upload ONE `(x, q)`
array pair, invalidate the cached multipoles — and the next evaluation
recomputes every drifting partition's multipoles on device in a single
launch: zero per-partition host->device multipole transfers (the
`DeviceMemo.misses` counter is the transfer meter tests pin).

Serving tier on top (`fused=True`, default on device backends): the phases
above fuse into ONE donated entry-computation launch per warm `evaluate()` /
within-slack `step_drift()` (engine.fused), AOT-compiled once per *shape
class* through `engine.exe_cache.ExecutableCache` — a second geometry with
the same padded dims/statics pays zero XLA compilations.  Payload buffers
are donated and threaded back out (XLA input-output aliasing); DeviceMemo
table views are never donated (see `fmm.device_hook`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.engine import fused as _fused_mod
from repro.core.engine.exe_cache import (ExecutableCache, GLOBAL_CACHE,
                                         resolve_cache)
from repro.core.engine.m2l import far_tail_kernel, m2p_vals_kernel
from repro.core.engine.p2p import p2p_bucket_vals, p2p_stream_vals
from repro.core.engine.schedules import (BatchedUpwardSchedule, EngineTables,
                                         build_batched_upward,
                                         build_engine_tables,
                                         build_p2p_stream_tables,
                                         shape_class_digest, stack_bodies,
                                         stack_reference_bodies)
from repro.core.engine.traversal import (default_traversal_backend,
                                         device_dual_traversal,
                                         partition_drift,
                                         resolve_traversal_backend,
                                         restack_payload)
from repro.core.engine.upward import batched_upward, batched_upward_kernel
from repro.core.fmm import device_hook
from repro.core.multipole import get_operators
from repro.resilience import faults as _faults

__all__ = ["DeviceEngine", "EngineTables", "BatchedUpwardSchedule",
           "build_engine_tables", "build_batched_upward", "batched_upward",
           "batched_upward_kernel", "build_p2p_stream_tables", "stack_bodies",
           "default_engine_enabled", "default_use_kernels",
           "default_fused_enabled", "default_p2p_stream",
           "default_traversal_backend", "resolve_traversal_backend",
           "device_dual_traversal", "partition_drift", "restack_payload",
           "ExecutableCache", "GLOBAL_CACHE", "resolve_cache",
           "shape_class_digest"]


def default_engine_enabled() -> bool:
    """Engine dispatch default: batched execution wins on any real device
    backend (launch count dominates); the per-partition reference path stays
    the CPU default so CPU test runs pin it byte-identically."""
    return jax.default_backend() not in ("cpu",)


def default_use_kernels() -> bool:
    """Pallas kernel dispatch default: only where the kernels actually
    COMPILE — the same predicate repro.kernels.ops uses for interpret mode.
    On backends where Pallas would run interpreted (traced Python, orders of
    magnitude slower than the jnp path), the engine keeps the jnp route."""
    from repro.kernels import ops
    return not ops.INTERPRET


def default_fused_enabled() -> bool:
    """Fused-megakernel dispatch default: mirror `default_engine_enabled` —
    launch overhead only dominates where there is a launch (device
    backends); on CPU the per-phase engine stays the default so CPU test
    runs keep pinning its counters byte-identically.  Opt in anywhere with
    `fused=True`."""
    return jax.default_backend() not in ("cpu",)


def default_p2p_stream() -> bool:
    """Streaming-P2P dispatch default: only where the kernel's DMA pipeline
    is real — the TPU backend.  Elsewhere (CPU tests, GPU) the gathered
    buckets stay the default; opt in anywhere with `p2p_stream=True` (on CPU
    that routes through the XLA slab-gather program unless `use_kernels`
    forces interpret-mode kernel emulation)."""
    return jax.default_backend() == "tpu"


class DeviceEngine:
    """Batched device executor for one `GeometryPlan` (one tree *structure*;
    the numeric payload may rebind across timesteps via `refresh_payload`).

    Parameters
    ----------
    geometry : api.GeometryPlan
    use_kernels : route P2P buckets through the Pallas kernels; default
        `default_use_kernels()` (on iff a device backend is present).
    interpret : force Pallas interpret mode (CI smoke on CPU runners).
    asarray : device-upload hook (api.DeviceMemo or compatible); a fresh
        `DeviceMemo` is created when omitted.  `memo.misses` counts every
        host->device transfer the engine performs.
    fused : collapse each warm `evaluate()` / `step_drift()` into ONE
        donated entry-computation launch (engine.fused), AOT-compiled
        through the shape-class executable cache; default
        `default_fused_enabled()` (on iff a device backend is present).
        The per-phase path stays available on the same engine and is the
        pinned numeric comparison.
    exe_cache : `exe_cache.ExecutableCache` for fused executables; the
        process-wide `GLOBAL_CACHE` when omitted, so geometries of one
        shape class share one compilation across sessions.
    p2p_stream : run the P2P near field through the unified streaming
        kernel (`kernels.p2p_stream`: in-kernel slab gathers, double-
        buffered VMEM DMA, all width classes one grid) instead of one
        gathered launch per width-class bucket; default
        `default_p2p_stream()` (on iff TPU).  Falls back to the gathered
        buckets per geometry when the stream-table contiguity invariant
        does not hold (`p2p.stream.fallbacks` counter).
    """

    def __init__(self, geometry, *, use_kernels: bool | None = None,
                 interpret: bool | None = None, asarray=None,
                 fused: bool | None = None, exe_cache=None,
                 p2p_stream: bool | None = None):
        from repro.core.api import DeviceMemo
        self.geo = geometry
        self.use_kernels = (default_use_kernels() if use_kernels is None
                            else bool(use_kernels))
        self.interpret = interpret
        self.fused = default_fused_enabled() if fused is None else bool(fused)
        self.p2p_stream = (default_p2p_stream() if p2p_stream is None
                           else bool(p2p_stream))
        self._stream = None          # unified stream tables, built lazily
        self._stream_params = None   # autotuned (block_t, n_buffers)
        self.exe_cache = resolve_cache(exe_cache)
        self._entries: dict = {}     # (kind, x64) -> (CompiledEntry, tabs)
        self.launch_log: list = []   # (kind, key) per fused dispatch
        self.memo = DeviceMemo() if asarray is None else asarray
        self._aa = device_hook(self.memo)
        self.tables: EngineTables = build_engine_tables(geometry)
        self._x_pad, self._q_pad = stack_bodies(geometry.trees,
                                                self.tables.n_bodies_max)
        self._ops = get_operators(geometry.p)
        self._M = None               # cached device multipoles (P, Cmax, nk)
        self._x_ref_pad = None       # stacked slack reference, built lazily
        self._pending_x_pad = None   # device payload staged by step_drift
        self.payload_refreshes = 0
        # f32 guard band for drift-vs-slack decisions: step_drift measures in
        # f32 (inputs rounded before subtraction), so its absolute error is
        # a few ulps of the coordinate scale.  Decisions within the band must
        # fall back to the exact f64 host revalidation (api.FMMSession.step).
        self.drift_guard = float(4 * np.finfo(np.float32).eps
                                 * max(np.abs(geometry.x_ref).max(), 1.0))

    # ----------------------------------------------------------- payload --
    def refresh_payload(self, geometry, *, use_pending: bool = False) -> None:
        """Rebind to a same-structure geometry (within-slack step): restack
        the (x, q) payload and invalidate cached device multipoles.  Index
        tables — and their memoized device views — are reused untouched.

        With `use_pending=True` the device payload staged by the last
        `step_drift` call becomes the new x payload directly — the host never
        restacks and the step's only host->device transfer was `new_x` (the
        session guarantees q is unchanged on this path)."""
        self.geo = geometry
        if use_pending and self._pending_x_pad is not None:
            self._x_pad = self._pending_x_pad
        else:
            self._x_pad, self._q_pad = stack_bodies(geometry.trees,
                                                    self.tables.n_bodies_max)
        self._pending_x_pad = None
        self._M = None
        self.payload_refreshes += 1

    def discard_pending(self) -> None:
        self._pending_x_pad = None

    # ------------------------------------------------------------- fused --
    def _donatable(self, arr, dtype=None):
        """Upload (explicit copy) or pass through an array that is safe to
        DONATE to a fused launch.  Memo-resident views are rejected: donation
        deletes the buffer after the call, and the `DeviceMemo` would keep
        serving the dead view to every other consumer (the per-phase path,
        sibling engines) — the residency/donation contract of engine.fused
        and `fmm.device_hook`."""
        if isinstance(arr, jax.Array):
            if self.memo.is_resident(arr):
                raise TypeError(
                    "refusing to donate a DeviceMemo-resident view: donated "
                    "buffers are deleted after the launch, which would "
                    "poison the memo (engine.fused donation contract); pass "
                    "a fresh upload or a previous fused output instead")
            obs.counter_add("engine.donate.reuse")
            return arr if dtype is None else jnp.asarray(arr, dtype)
        # jnp.array (copy), never asarray: CPU zero-copy uploads alias the
        # caller's host buffer, and XLA would scribble over it on donation
        obs.counter_add("engine.donate.upload")
        return jnp.array(np.asarray(arr), dtype=dtype)

    def _payload_device(self):
        """The (x_pad, q_pad) payload as donatable device buffers: fresh
        copies on first use / after a host `refresh_payload`, previous fused
        outputs (aliased storage) on warm calls."""
        return (self._donatable(self._x_pad, jnp.float32),
                self._donatable(self._q_pad, jnp.float32))

    # ---------------------------------------------------------- streaming --
    def _measure_stream(self, block_t: int, n_buffers: int) -> float:
        """Time one streaming launch at candidate (block_t, n_buffers) —
        the `best_stream_params` measure closure on real backends (tables
        are rebuilt per block_t because the tiling depends on it)."""
        import time
        stream = build_p2p_stream_tables(self.tables.p2p_buckets, block_t)
        if stream is None:
            return float("inf")
        aa = self._aa
        fn = lambda: p2p_stream_vals(
            aa(self._x_pad, jnp.float32), aa(self._q_pad, jnp.float32),
            stream, use_kernels=True, interpret=self.interpret,
            asarray=self.memo, n_buffers=n_buffers)
        jax.block_until_ready(fn())          # compile + warm
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    def _stream_tables(self):
        """Resolve the unified stream tables for this geometry (lazily, once):
        autotune (block_t, n_buffers) for the stream shape class, build the
        tile table, and VERIFY the contiguous-run invariant — returns None
        (and flips the engine back to gathered buckets, counted at
        `p2p.stream.fallbacks`) when the invariant does not hold."""
        if not self.p2p_stream:
            return None
        if self._stream is not None:
            return self._stream
        t = self.tables
        if not t.p2p_buckets:
            self.p2p_stream = False
            return None
        from repro.kernels import ops as kops
        from repro.kernels.p2p import best_stream_params
        interp = (kops.INTERPRET if self.interpret is None
                  else bool(self.interpret))
        smax = max(b["s_idx"].shape[1] for b in t.p2p_buckets)
        wt_max = max(b["t_idx"].shape[1] for b in t.p2p_buckets)
        n_rows = sum(len(b["mask"]) for b in t.p2p_buckets)
        measure = (self._measure_stream
                   if self.use_kernels and not interp else None)
        bt, nb = best_stream_params(smax, n_rows, wt_max,
                                    interpret=interp, measure=measure)
        stream = build_p2p_stream_tables(t.p2p_buckets, bt)
        if stream is None:
            obs.counter_add("p2p.stream.fallbacks")
            self.p2p_stream = False
            return None
        self._stream = stream
        self._stream_params = (bt, nb)
        obs.counter_add("p2p.stream.builds")
        if obs.enabled():
            obs.event("p2p.stream.tables",
                      {"n_tiles": stream["n_tiles"],
                       "n_live_tiles": stream["n_live_tiles"],
                       "smax": stream["smax"], "block_t": bt,
                       "n_buffers": nb, "n_buckets": len(t.p2p_buckets)})
        return stream

    def _fused_entry(self, kind: str):
        """Resolve this engine's fused executable + uploaded tables for
        `kind` in ("evaluate", "step"), memoized per (kind, x64): the
        shape-class cache is consulted ONCE per engine lifetime, so its
        hit/miss counters meter per-geometry resolutions — a second
        same-shape-class geometry is exactly one `hits` increment and zero
        compilations."""
        x64 = bool(jax.config.jax_enable_x64)
        hit = self._entries.get((kind, x64))
        if hit is not None:
            return hit
        t = self.tables
        aa = self._aa
        if kind == "evaluate":
            donate = (0, 1)          # both payload halves alias outputs
            stream = self._stream_tables()
            flat = _fused_mod.flatten_eval_tables(t, stream=stream)
            if stream is not None:
                p2p_impl = "stream"
                nb = self._stream_params[1]
                block_ts = (stream["smax"], stream["block_t"], nb)
            else:
                p2p_impl = "gathered"
                nb = 2
                block_ts = _fused_mod.bucket_block_ts(
                    t, use_kernels=self.use_kernels, interpret=self.interpret)
            fn = _fused_mod.build_fused_evaluate(
                self._ops, t, use_kernels=self.use_kernels,
                interpret=self.interpret, block_ts=block_ts,
                acc_dtype=jnp.float64 if x64 else jnp.float32,
                stream=stream, n_buffers=nb)
            in_sds = (jax.ShapeDtypeStruct((t.n_parts, t.n_bodies_max, 3),
                                           jnp.float32),
                      jax.ShapeDtypeStruct((t.n_parts, t.n_bodies_max),
                                           jnp.float32))
        elif kind == "step":
            # donate x_pad only: new_x has no same-shape output to alias
            # onto, so donating it would just trigger XLA's unusable-buffer
            # warning without saving an allocation
            donate = (1,)
            if self._x_ref_pad is None:
                self._x_ref_pad = stack_reference_bodies(self.geo, t)
            flat = _fused_mod.flatten_step_tables(t, self._x_ref_pad)
            block_ts, p2p_impl = (), "gathered"   # step runs no P2P
            fn = _fused_mod.build_fused_step(t)
            in_sds = (jax.ShapeDtypeStruct((t.n, 3), jnp.float32),
                      jax.ShapeDtypeStruct((t.n_parts, t.n_bodies_max, 3),
                                           jnp.float32))
        else:
            raise ValueError(f"unknown fused entry kind {kind!r}")
        # memoized device views — the digest sees canonicalized dtypes
        tabs = {k: aa(v) for k, v in flat.items()}
        key = _fused_mod.executable_key(
            kind, shape_class_digest(tabs), n=t.n, n_parts=t.n_parts, p=t.p,
            theta=self.geo.theta, x64=x64, backend=jax.default_backend(),
            use_kernels=self.use_kernels, interpret=self.interpret,
            block_ts=block_ts, p2p_impl=p2p_impl)
        entry = self.exe_cache.get_or_compile(
            key, lambda: jax.jit(fn, donate_argnums=donate)
            .lower(*in_sds, tabs).compile())
        self._entries[(kind, x64)] = (entry, tabs)
        return entry, tabs

    def _evaluate_fused(self):
        """One donated launch: payload in, potential (and multipoles) out.
        The threaded-through payload outputs rebind the engine's handles —
        XLA aliases them onto the donated inputs' storage."""
        with obs.span("engine.fused_evaluate") as sp:
            # simulated-OOM seam: a RESOURCE_EXHAUSTED here is what an
            # oversubscribed accelerator raises on the entry launch, and
            # what the resilience ladder downgrades past
            _faults.fire("fused.launch")
            entry, tabs = self._fused_entry("evaluate")
            xd, qd = self._payload_device()
            phi, M, x_out, q_out = sp.fence(entry(xd, qd, tabs))
            obs.counter_add("engine.fused_launches")
            if self._stream is not None:
                obs.counter_add("p2p.stream.launches")
                obs.counter_add("p2p.stream.tiles",
                                self._stream["n_live_tiles"])
                obs.counter_add("p2p.stream.dma_tiles",
                                2 * self._stream["n_live_tiles"])
        self._x_pad, self._q_pad = x_out, q_out
        self._M = M
        self.launch_log.append(("evaluate", entry.key))
        return phi

    def step_drift(self, new_x) -> tuple:
        """Batched MAC-slack revalidation: upload `new_x` ONCE, restack it
        into the (P, Nmax, 3) payload envelope on device through the frozen
        global-id tables, and reduce every partition's drift (vs the slack
        reference `x_ref`) and changed flag (vs the current payload) in one
        launch — replacing the session's per-partition NumPy loop.  The
        restacked payload is staged for `refresh_payload(use_pending=True)`.

        Returns (drift (P,) float64, changed (P,) bool) host arrays.

        Fused mode runs the restack + both reductions as ONE donated entry
        computation (engine.fused.build_fused_step): `new_x` uploads as a
        donated copy, the current payload is donated and threaded back out
        (aliased), and the restacked envelope is staged as the pending
        payload without ever touching the host."""
        if self.fused:
            with obs.span("engine.step_drift"):
                entry, tabs = self._fused_entry("step")
                nd = self._donatable(new_x, jnp.float32)
                xd = self._donatable(self._x_pad, jnp.float32)
                drift, changed, x_new, x_out = entry(nd, xd, tabs)
                self._x_pad = x_out
                self._pending_x_pad = x_new
                self.launch_log.append(("step", entry.key))
                obs.counter_add("engine.fused_launches")
                return (np.asarray(drift, np.float64),
                        np.asarray(changed, bool))
        with obs.span("engine.step_drift"):
            t = self.tables
            aa = self._aa
            if self._x_ref_pad is None:
                self._x_ref_pad = stack_reference_bodies(self.geo, t)
            xd = aa(new_x, jnp.float32)
            x_pad = restack_payload(xd, aa(t.orig_idx), aa(t.flat_idx),
                                    t.n_parts, t.n_bodies_max)
            drift, changed = partition_drift(x_pad, aa(self._x_ref_pad),
                                             aa(self._x_pad, jnp.float32))
            self._pending_x_pad = x_pad
            return (np.asarray(drift, np.float64),
                    np.asarray(changed, bool))

    # ------------------------------------------------------------ passes --
    def upward(self):
        """Device multipoles (P, n_cells_max, nk); cached per payload."""
        if self._M is None:
            with obs.span("engine.upward") as sp:
                self._M = sp.fence(
                    batched_upward(self._ops, self._x_pad, self._q_pad,
                                   self.tables.up, asarray=self.memo))
        return self._M

    def _phase_values(self):
        """Run the three batched phases; yields (idx, valid, vals) value
        tables (device f32) for the final accumulation."""
        t = self.tables
        aa = self._aa
        M = self.upward()
        x = aa(self._x_pad, jnp.float32)
        q = aa(self._q_pad, jnp.float32)
        ut = t.up.tables

        with obs.span("engine.far_field") as sp:
            l2p_vals = sp.fence(far_tail_kernel(
                self._ops, M, x,
                {k: aa(v) for k, v in t.m2l.items()},
                aa(ut["down_ids"]), aa(ut["down_parents"]),
                aa(ut["down_mask"]), aa(ut["down_d"]), aa(ut["leaves"]),
                aa(ut["leaf_mask"]), aa(ut["leaf_centers"]),
                aa(ut["leaf_idx"])))
        yield t.l2p_t_idx, ut["leaf_valid"], l2p_vals

        stream = self._stream_tables()
        if stream is not None:
            with obs.span("engine.p2p_stream") as sp:
                vals = sp.fence(p2p_stream_vals(
                    x, q, stream, use_kernels=self.use_kernels,
                    interpret=self.interpret, asarray=self.memo,
                    n_buffers=self._stream_params[1]))
                obs.counter_add("p2p.stream.launches")
                obs.counter_add("p2p.stream.tiles",
                                stream["n_live_tiles"])
                # two slab DMAs (sources + targets) per live tile
                obs.counter_add("p2p.stream.dma_tiles",
                                2 * stream["n_live_tiles"])
            yield stream["out_idx"], stream["out_valid"], vals
        else:
            for bucket in t.p2p_buckets:
                with obs.span("engine.p2p_bucket") as sp:
                    vals = sp.fence(p2p_bucket_vals(
                        x, q, bucket, use_kernels=self.use_kernels,
                        interpret=self.interpret, asarray=self.memo,
                        to_host=False))
                yield bucket["t_idx"], bucket["t_valid"], vals

        if t.m2p["b"].shape[0]:
            with obs.span("engine.m2p") as sp:
                vals = sp.fence(m2p_vals_kernel(
                    self._ops, M, x, aa(t.m2p["b"]), aa(t.m2p["centers"]),
                    aa(t.m2p["mask"]), aa(t.m2p["t_idx"])))
            yield t.m2p["t_idx"], t.m2p["t_valid"], vals

    def evaluate_device(self) -> jnp.ndarray:
        """Full potential in original body order as ONE device (N,) float64
        array — the whole pipeline from payload to potentials stays on the
        accelerator.  Requires x64 on the backend (jax_enable_x64): without
        it the f64 segment sums would silently truncate to f32, so this
        raises instead (the host accumulation path keeps f64 precision when
        x64 is off)."""
        if not jax.config.jax_enable_x64:
            raise RuntimeError(
                "evaluate_device requires jax_enable_x64 (device f64 "
                "accumulation); use evaluate() for host f64 accumulation")
        if self.fused:
            return self._evaluate_fused()
        t = self.tables
        aa = self._aa
        phi_flat = jnp.zeros(t.n_parts * t.n_bodies_max, jnp.float64)
        for idx, valid, vals in self._phase_values():
            contrib = jnp.where(aa(valid).ravel(),
                                vals.astype(jnp.float64).ravel(), 0.0)
            phi_flat = phi_flat.at[aa(idx).ravel()].add(contrib)
        return (jnp.zeros(t.n, jnp.float64)
                .at[aa(t.orig_idx)].set(phi_flat[aa(t.flat_idx)]))

    def evaluate(self) -> np.ndarray:
        """Full potential in original body order (float64, host).

        With x64 enabled on the backend, the f64 accumulation itself runs on
        device (`evaluate_device`) and the only host transfer is the final
        (N,) potential; otherwise each phase's padded f32 value tables are
        accumulated in host float64 (identical precision to the reference
        executors, which is what pins the engine against them).

        Fused mode is one donated launch either way; without x64 the fused
        program can only accumulate in device f32 — marginally looser than
        this host-f64 path (tight-tolerance equivalence holds under x64)."""
        if jax.config.jax_enable_x64:
            return np.asarray(self.evaluate_device())
        if self.fused:
            return np.asarray(self._evaluate_fused(), np.float64)
        t = self.tables
        phi_flat = np.zeros(t.n_parts * t.n_bodies_max)
        for idx, valid, vals in self._phase_values():
            np.add.at(phi_flat, np.asarray(idx).ravel(),
                      np.where(np.asarray(valid).ravel(),
                               np.asarray(vals, np.float64).ravel(), 0.0))
        phi = np.zeros(t.n)
        phi[t.orig_idx] = phi_flat[t.flat_idx]
        return phi
