"""Device evaluation engine: batched multi-tree FMM execution.

Fourth tier of the pipeline — `plan_geometry` (host geometry) ->
`schedule_comm` (protocol schedules) -> **`DeviceEngine`** (batched device
execution) -> `FMMSession` (orchestration).  A `DeviceEngine` is compiled
once per `GeometryPlan`: `schedules.build_engine_tables` stacks every
partition's frozen per-tree tables into `(n_parts, ...)` envelopes, and
evaluation then runs

  1. one batched upward launch (`upward.batched_upward_kernel`) — P2M + M2M
     for ALL partitions, replacing the per-partition Python sweep;
  2. one far-field launch (`m2l.far_tail_kernel`) — a segment-summed M2L
     over every (receiver, sender) pair reading sender-global device
     multipoles (grafted LETs never materialize on the host), the stacked
     downward sweep, and the leaf evaluation;
  3. one launch per P2P width-class bucket (`p2p.p2p_bucket_vals`),
     Pallas-backed with per-(S, n_pairs) autotuned block sizes on device
     backends, jnp reference on CPU; plus one batched M2P fallback launch.

Float64 accumulation of the f32 value tables happens once on the host, at
the API boundary — identical precision to the reference executors, which is
what pins the engine allclose to `api.execute_geometry`.

Timesteps: index tables are payload-independent, so a within-slack
`FMMSession.step` calls `refresh_payload` — restack + upload ONE `(x, q)`
array pair, invalidate the cached multipoles — and the next evaluation
recomputes every drifting partition's multipoles on device in a single
launch: zero per-partition host->device multipole transfers (the
`DeviceMemo.misses` counter is the transfer meter tests pin).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine.m2l import far_tail_kernel, m2p_vals_kernel
from repro.core.engine.p2p import p2p_bucket_vals
from repro.core.engine.schedules import (BatchedUpwardSchedule, EngineTables,
                                         build_batched_upward,
                                         build_engine_tables, stack_bodies)
from repro.core.engine.upward import batched_upward, batched_upward_kernel
from repro.core.fmm import device_hook
from repro.core.multipole import get_operators

__all__ = ["DeviceEngine", "EngineTables", "BatchedUpwardSchedule",
           "build_engine_tables", "build_batched_upward", "batched_upward",
           "batched_upward_kernel", "stack_bodies", "default_engine_enabled",
           "default_use_kernels"]


def default_engine_enabled() -> bool:
    """Engine dispatch default: batched execution wins on any real device
    backend (launch count dominates); the per-partition reference path stays
    the CPU default so CPU test runs pin it byte-identically."""
    return jax.default_backend() not in ("cpu",)


def default_use_kernels() -> bool:
    """Pallas kernel dispatch default: only where the kernels actually
    COMPILE — the same predicate repro.kernels.ops uses for interpret mode.
    On backends where Pallas would run interpreted (traced Python, orders of
    magnitude slower than the jnp path), the engine keeps the jnp route."""
    from repro.kernels import ops
    return not ops.INTERPRET


class DeviceEngine:
    """Batched device executor for one `GeometryPlan` (one tree *structure*;
    the numeric payload may rebind across timesteps via `refresh_payload`).

    Parameters
    ----------
    geometry : api.GeometryPlan
    use_kernels : route P2P buckets through the Pallas kernels; default
        `default_use_kernels()` (on iff a device backend is present).
    interpret : force Pallas interpret mode (CI smoke on CPU runners).
    asarray : device-upload hook (api.DeviceMemo or compatible); a fresh
        `DeviceMemo` is created when omitted.  `memo.misses` counts every
        host->device transfer the engine performs.
    """

    def __init__(self, geometry, *, use_kernels: bool | None = None,
                 interpret: bool | None = None, asarray=None):
        from repro.core.api import DeviceMemo
        self.geo = geometry
        self.use_kernels = (default_use_kernels() if use_kernels is None
                            else bool(use_kernels))
        self.interpret = interpret
        self.memo = DeviceMemo() if asarray is None else asarray
        self._aa = device_hook(self.memo)
        self.tables: EngineTables = build_engine_tables(geometry)
        self._x_pad, self._q_pad = stack_bodies(geometry.trees,
                                                self.tables.n_bodies_max)
        self._ops = get_operators(geometry.p)
        self._M = None               # cached device multipoles (P, Cmax, nk)
        self.payload_refreshes = 0

    # ----------------------------------------------------------- payload --
    def refresh_payload(self, geometry) -> None:
        """Rebind to a same-structure geometry (within-slack step): restack
        the (x, q) payload and invalidate cached device multipoles.  Index
        tables — and their memoized device views — are reused untouched."""
        self.geo = geometry
        self._x_pad, self._q_pad = stack_bodies(geometry.trees,
                                                self.tables.n_bodies_max)
        self._M = None
        self.payload_refreshes += 1

    # ------------------------------------------------------------ passes --
    def upward(self):
        """Device multipoles (P, n_cells_max, nk); cached per payload."""
        if self._M is None:
            self._M = batched_upward(self._ops, self._x_pad, self._q_pad,
                                     self.tables.up, asarray=self.memo)
        return self._M

    def evaluate(self) -> np.ndarray:
        """Full potential in original body order (float64, host)."""
        t = self.tables
        aa = self._aa
        M = self.upward()
        x = aa(self._x_pad, jnp.float32)
        q = aa(self._q_pad, jnp.float32)
        ut = t.up.tables

        l2p_vals = far_tail_kernel(
            self._ops, M, x,
            {k: aa(v) for k, v in t.m2l.items()},
            aa(ut["down_ids"]), aa(ut["down_parents"]), aa(ut["down_mask"]),
            aa(ut["down_d"]), aa(ut["leaves"]), aa(ut["leaf_mask"]),
            aa(ut["leaf_centers"]), aa(ut["leaf_idx"]))

        phi_flat = np.zeros(t.n_parts * t.n_bodies_max)
        np.add.at(phi_flat, t.l2p_t_idx.ravel(),
                  np.where(ut["leaf_valid"].ravel(),
                           np.asarray(l2p_vals, np.float64).ravel(), 0.0))

        for bucket in t.p2p_buckets:
            vals = p2p_bucket_vals(x, q, bucket, use_kernels=self.use_kernels,
                                   interpret=self.interpret, asarray=self.memo)
            np.add.at(phi_flat, bucket["t_idx"].ravel(),
                      np.where(bucket["t_valid"].ravel(),
                               vals.astype(np.float64).ravel(), 0.0))

        if t.m2p["b"].shape[0]:
            vals = m2p_vals_kernel(self._ops, M, x, aa(t.m2p["b"]),
                                   aa(t.m2p["centers"]), aa(t.m2p["mask"]),
                                   aa(t.m2p["t_idx"]))
            np.add.at(phi_flat, t.m2p["t_idx"].ravel(),
                      np.where(t.m2p["t_valid"].ravel(),
                               np.asarray(vals, np.float64).ravel(), 0.0))

        phi = np.zeros(t.n)
        phi[t.orig_idx] = phi_flat[t.flat_idx]
        return phi
