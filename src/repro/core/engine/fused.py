"""Fused FMM megakernel: the whole evaluation (and the whole step
revalidation) as ONE donated XLA entry computation.

The per-phase engine (`DeviceEngine._phase_values` / `evaluate_device`)
already batches each FMM phase across partitions, but a warm `evaluate()`
still dispatches one jitted call per phase — upward, far-field tail, one per
P2P width bucket, M2P — plus the final accumulation scatter.  At
small-to-medium N the launch overhead of that handful of dispatches dwarfs
the FLOP time, exactly the per-message-overhead regime the paper's §4 bulk
exchange collapses.  This module collapses the launches the same way: the
builders below close over the *static* structure (expansion order, bucket
count, padded dims, kernel dispatch flags) and call the existing phase
kernels — `batched_upward_kernel`, `far_tail_kernel`, `m2p_vals_kernel`,
the bucketed P2P — inside one trace, so nested jits inline and the whole
pipeline compiles to a single entry computation with trace-identical
numerics to the per-phase path (which stays the pinned comparison).

Donation vs `DeviceMemo` residency
----------------------------------
The fused program takes two argument classes with opposite lifetimes:

  - **frozen index tables** — memoized device views served by the engine's
    `DeviceMemo`, shared with the per-phase path and alive for the
    geometry's lifetime.  These are NEVER donated: a donated buffer is
    deleted after the call, and the memo would go on serving a dead view.
  - **payload / accumulator buffers** — the `(P, Nmax, 3)`/`(P, Nmax)`
    coordinate/charge envelopes (and the step's `new_x` upload).  These are
    ALWAYS donated (`donate_argnums`), so XLA reuses their storage for the
    outputs in place of allocating fresh buffers every timestep.  Payload
    arrays are threaded through to outputs, which XLA turns into
    input-output aliasing; the engine rebinds its handles from the outputs
    after every call.  Donated uploads are explicit copies (`jnp.array`) —
    on CPU a zero-copy `asarray` view would let XLA scribble over caller
    memory — and `DeviceEngine._donatable` raises `TypeError` if a
    memo-resident view is ever offered for donation.

Accumulation dtype: with x64 enabled the potential accumulates on device in
float64 (bit-for-bit the `evaluate_device` contract); without x64 the fused
program can only accumulate in f32 — slightly looser than the per-phase
path's *host* f64 accumulation, documented and tested at a looser
tolerance.  Tight-tolerance equivalence tests therefore run under x64.

Executable identity: `executable_key` folds `schedules.shape_class_digest`
(dtype/shape of every table as uploaded — x64 canonicalization included)
with the scalar statics; `exe_cache.ExecutableCache` memoizes the
`jax.jit(...).lower(...).compile()` product per key, so a new geometry of
an already-seen shape class pays zero XLA time.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.m2l import far_tail_kernel, m2p_vals_kernel
from repro.core.engine.traversal import _drift_changed_kernel, _restack_kernel
from repro.core.engine.upward import batched_upward_kernel
from repro.core.fmm import _p2p_vals

__all__ = ["flatten_eval_tables", "flatten_step_tables", "bucket_block_ts",
           "build_fused_evaluate", "build_fused_step", "executable_key",
           "theta_bucket"]

_UP_KEYS = ("leaves", "leaf_mask", "leaf_centers", "leaf_idx", "leaf_valid",
            "up_ids", "up_parents", "up_mask", "up_d",
            "down_ids", "down_parents", "down_mask", "down_d")


# ------------------------------------------------------------- table views --
def flatten_eval_tables(tables, stream: dict | None = None) -> dict:
    """Flat {name: host array} of every frozen table the fused evaluate
    reads — one pytree argument, memoized per-leaf by the engine's memo.
    Keys are stable across builds so the pytree structure (and therefore the
    compiled executable) depends only on the shape class.

    With `stream` (a `schedules.build_p2p_stream_tables` dict) the per-bucket
    gather tables are replaced by the unified stream tables — the fused
    program never touches the bucket indices on that path."""
    flat = {k: tables.up.tables[k] for k in _UP_KEYS}
    for k, v in tables.m2l.items():
        flat[f"m2l_{k}"] = v
    for k, v in tables.m2p.items():
        flat[f"m2p_{k}"] = v
    if stream is not None:
        flat["p2ps_meta"] = stream["meta"]
        flat["p2ps_out_idx"] = stream["out_idx"]
        flat["p2ps_out_valid"] = stream["out_valid"]
    else:
        for i, b in enumerate(tables.p2p_buckets):
            for k, v in b.items():
                flat[f"p2p{i}_{k}"] = v
    flat["l2p_t_idx"] = tables.l2p_t_idx
    flat["orig_idx"] = tables.orig_idx
    flat["flat_idx"] = tables.flat_idx
    return flat


def flatten_step_tables(tables, x_ref_pad) -> dict:
    """Flat frozen tables for the fused step revalidation: the orig->flat
    restack gathers plus the stacked slack reference."""
    return {"orig_idx": tables.orig_idx, "flat_idx": tables.flat_idx,
            "x_ref_pad": x_ref_pad}


def bucket_block_ts(tables, *, use_kernels: bool, interpret: bool | None):
    """Per-bucket Pallas target block sizes, resolved on the host at build
    time: `best_block_t` times candidates on a real backend, which cannot
    happen inside a trace, so the fused program bakes the choice in as a
    static (and the executable key carries it)."""
    if not use_kernels:
        return (None,) * len(tables.p2p_buckets)
    from repro.kernels import ops as kops
    from repro.kernels.p2p import best_block_t
    interp = kops.INTERPRET if interpret is None else bool(interpret)
    out = []
    for b in tables.p2p_buckets:
        n_pairs, ws = b["s_idx"].shape
        out.append(best_block_t(ws, n_pairs, b["t_idx"].shape[1],
                                interpret=interp))
    return tuple(out)


# ----------------------------------------------------------------- builders --
def build_fused_evaluate(ops, tables, *, use_kernels: bool,
                         interpret: bool | None, block_ts, acc_dtype,
                         stream: dict | None = None, n_buffers: int = 2):
    """Close over the static structure and return the fused evaluate
    `fused(x_pad, q_pad, tab) -> (phi, M, x_pad, q_pad)` — jit it with
    `donate_argnums=(0, 1)`.  `tab` is `flatten_eval_tables` uploaded; the
    donated payload pair is threaded to the outputs for aliasing, and the
    device multipoles `M` come back so the engine can serve `upward()`
    without a second launch.

    With `stream` the near field runs as ONE streaming grid over the unified
    tile table (kernels.p2p_stream with `use_kernels`, the XLA slab-gather
    program without) instead of one gather + `pallas_call` per width-class
    bucket — the donated payload is transposed once into the (4, F) slab
    source in-trace and no per-bucket gathered operands ever hit HBM."""
    from repro import obs
    if obs.enabled():
        obs.event("engine.fused_build",
                  {"kind": "evaluate", "n": tables.n,
                   "n_parts": tables.n_parts,
                   "n_buckets": len(tables.p2p_buckets),
                   "use_kernels": bool(use_kernels),
                   "p2p_impl": "stream" if stream is not None else "gathered"})
    P, Cmax = tables.n_parts, tables.n_cells_max
    Nmax, n = tables.n_bodies_max, tables.n
    n_buckets = len(tables.p2p_buckets)
    has_m2p = tables.m2p["b"].shape[0] > 0
    if use_kernels:
        from repro.kernels import ops as kops
        from repro.kernels.p2p import p2p_pallas
        interp = kops.INTERPRET if interpret is None else bool(interpret)

    def fused(x_pad, q_pad, tab):
        M = batched_upward_kernel(
            ops, x_pad, q_pad, tab["leaves"], tab["leaf_mask"],
            tab["leaf_centers"], tab["leaf_idx"], tab["leaf_valid"],
            tab["up_ids"], tab["up_parents"], tab["up_mask"], tab["up_d"],
            n_cells=Cmax)
        m2l = {k: tab[f"m2l_{k}"] for k in ("src", "tgt", "mask", "d")}
        l2p_vals = far_tail_kernel(
            ops, M, x_pad, m2l, tab["down_ids"], tab["down_parents"],
            tab["down_mask"], tab["down_d"], tab["leaves"], tab["leaf_mask"],
            tab["leaf_centers"], tab["leaf_idx"])

        phi_flat = jnp.zeros(P * Nmax, acc_dtype)

        def add(pf, idx, valid, vals):
            contrib = jnp.where(valid.ravel(),
                                vals.astype(acc_dtype).ravel(),
                                jnp.zeros((), acc_dtype))
            return pf.at[idx.ravel()].add(contrib)

        phi_flat = add(phi_flat, tab["l2p_t_idx"], tab["leaf_valid"],
                       l2p_vals)

        if stream is not None:
            from repro.core.engine.p2p import (p2p_stream_gathered,
                                               stream_payload)
            payload = stream_payload(x_pad, q_pad, stream["pad"])
            if use_kernels:
                from repro.kernels.p2p_stream import p2p_stream
                vals = p2p_stream(tab["p2ps_meta"], payload,
                                  block_t=stream["block_t"],
                                  smax=stream["smax"], n_buffers=n_buffers,
                                  interpret=interp)
            else:
                vals = p2p_stream_gathered(tab["p2ps_meta"], payload,
                                           block_t=stream["block_t"],
                                           smax=stream["smax"])
            phi_flat = add(phi_flat, tab["p2ps_out_idx"],
                           tab["p2ps_out_valid"], vals)
        else:
            x_flat = x_pad.reshape(-1, 3)
            q_flat = q_pad.reshape(-1)
            for i in range(n_buckets):
                t_idx, s_idx = tab[f"p2p{i}_t_idx"], tab[f"p2p{i}_s_idx"]
                xt, xs = x_flat[t_idx], x_flat[s_idx]
                qs = jnp.where(tab[f"p2p{i}_s_valid"], q_flat[s_idx], 0.0)
                if use_kernels:
                    vals = p2p_pallas(qs, xs, xt, interpret=interp,
                                      block_t=block_ts[i]) \
                        * tab[f"p2p{i}_mask"][:, None]
                else:
                    vals = _p2p_vals(xt, xs, qs, tab[f"p2p{i}_mask"])
                phi_flat = add(phi_flat, t_idx, tab[f"p2p{i}_t_valid"], vals)

        if has_m2p:
            vals = m2p_vals_kernel(ops, M, x_pad, tab["m2p_b"],
                                   tab["m2p_centers"], tab["m2p_mask"],
                                   tab["m2p_t_idx"])
            phi_flat = add(phi_flat, tab["m2p_t_idx"], tab["m2p_t_valid"],
                           vals)

        phi = (jnp.zeros(n, acc_dtype)
               .at[tab["orig_idx"]].set(phi_flat[tab["flat_idx"]]))
        return phi, M, x_pad, q_pad

    return fused


def build_fused_step(tables):
    """Fused within-slack step revalidation
    `fused(new_x, x_pad, tab) -> (drift, changed, x_new, x_pad)` — jit with
    `donate_argnums=(1,)`.  One launch restacks the uploaded `new_x` into
    the payload envelope and reduces every partition's drift/changed flags;
    `x_new` is the staged next payload and the previous `x_pad` is threaded
    back out so the engine keeps a live handle (donated -> aliased).
    `new_x` is NOT donated: it has no same-shape output to alias onto."""
    from repro import obs
    if obs.enabled():
        obs.event("engine.fused_build",
                  {"kind": "step", "n": tables.n,
                   "n_parts": tables.n_parts})
    P, Nmax = tables.n_parts, tables.n_bodies_max

    def fused(new_x, x_pad, tab):
        x_new = _restack_kernel(new_x, tab["orig_idx"], tab["flat_idx"],
                                shape=(P, Nmax))
        drift, changed = _drift_changed_kernel(x_new, tab["x_ref_pad"], x_pad)
        return drift, changed, x_new, x_pad

    return fused


# --------------------------------------------------------------- cache key --
def theta_bucket(theta: float) -> int:
    """MAC parameter bucketed to 1/16ths: theta only shapes the tables (the
    program text is theta-independent), but keying on the bucket keeps one
    executable per serving configuration — and gives the cache tests a
    dial that misses without touching the geometry."""
    return int(round(float(theta) * 16.0))


def executable_key(kind: str, digest: str, *, n: int, n_parts: int, p: int,
                   theta: float, x64: bool, backend: str, use_kernels: bool,
                   interpret, block_ts=(), p2p_impl: str = "gathered") -> tuple:
    """Shape-class key for one fused executable: everything that can change
    the compiled program (digest = per-table dtypes/shapes as uploaded,
    padded dims, statics) plus the conservative serving knobs.  `p2p_impl`
    names the near-field kernel variant ("gathered" per-bucket launches vs
    the unified "stream" grid); on the stream path `block_ts` carries the
    stream statics `(smax, block_t, n_buffers)` instead of per-bucket
    blocks — either way the tuple is part of the program text."""
    return (kind, digest, int(n), int(n_parts), int(p), theta_bucket(theta),
            bool(x64), str(backend), bool(use_kernels),
            None if interpret is None else bool(interpret), tuple(block_ts),
            str(p2p_impl))
