"""Batched multi-tree schedules: stack every partition's frozen per-tree
tables into `(n_parts, ...)` arrays one device launch can consume.

The per-tree plan layer (repro.core.plan) freezes padded index tables for ONE
tree / ONE (target, source) pair; the reference executors (repro.core.fmm)
then sweep partitions in a Python loop — one launch per partition per pass.
This module removes the loop at the *data* level: it pads all partitions'
tables to shared power-of-two envelopes and stacks them, so the engine
kernels (engine.upward / engine.m2l / engine.p2p) run each FMM phase for
every partition in a single vmapped launch.

Conventions shared by every stacked table:

  - Global cell ids:  cell `c` of partition `p`  ->  `p * n_cells_max + c`;
    multipoles/locals live in one `(P * n_cells_max, nk)` flat array.
  - Global body ids:  sorted body `b` of partition `p` -> `p * n_bodies_max
    + b`; coordinates/charges live in `(P, n_bodies_max, ...)` payload arrays
    (`stack_bodies`) that rebind each timestep while every index table here
    stays frozen (and therefore uploads to the device exactly once).
  - Empty partitions carry all-zero masks: their rows gather partition 0's
    slot 0 (always in range) and contribute exactly 0.
  - Level schedules are stacked twice: bottom-aligned for the upward pass
    (slot 0 = each tree's deepest level, so M2M runs children-first no matter
    how depths differ) and top-aligned for the downward pass.
  - Grafted-LET indices are translated to *sender-global* ids at build time
    via `LETData.cell_src` / `body_src`: the engine never materializes a LET
    payload on the host — remote M2L/M2P/P2P read the sender's device-resident
    multipoles and bodies directly.
  - Pair arrays may arrive as device (jax) arrays — e.g. from the device
    traversal tier — every builder funnels through `np.asarray`, paying at
    most one readback per table build (tables are then frozen for the
    geometry's lifetime).  Conversely `stack_reference_bodies` +
    `engine.traversal.restack_payload` keep the per-timestep payload path
    device-side: a step uploads new_x once and the stacked envelope is
    produced by an on-device scatter, never a host restack.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.plan import bucket_size
from repro.resilience import faults as _faults

__all__ = ["BatchedUpwardSchedule", "EngineTables", "build_batched_upward",
           "build_engine_tables", "build_p2p_stream_tables", "stack_bodies",
           "stack_reference_bodies", "shape_class_digest"]


def shape_class_digest(tables: dict) -> str:
    """Digest of a flat {name: array} table set's *shape class*: every
    entry's name, dtype and shape — never its values.  Two geometries with
    equal digests lower to identical fused programs (`engine.fused`), which
    is what lets `exe_cache.ExecutableCache` serve the second one without
    touching XLA.  Hash the arrays **as they will be fed to the program**
    (the memoized device views): jax canonicalizes int64 host tables to
    int32 when x64 is off, so the device dtype — not the host dtype — is
    the compiled program's signature."""
    h = hashlib.sha1()
    for name in sorted(tables):
        a = tables[name]
        h.update(f"{name}:{np.dtype(a.dtype).name}:{tuple(a.shape)};"
                 .encode())
    return h.hexdigest()


# ---------------------------------------------------------------- helpers --
def _stack(arrs, shape, dtype, fill=0):
    """Stack ragged per-partition arrays into (P, *shape), padding with
    `fill`; None entries (empty partitions) stay all-fill."""
    out = np.full((len(arrs),) + tuple(shape), fill, dtype=dtype)
    for i, a in enumerate(arrs):
        if a is None:
            continue
        a = np.asarray(a)
        out[i][tuple(slice(0, s) for s in a.shape)] = a
    return out


def _pad_rows(rows: dict, n: int, bucket: int, replicate: bool) -> dict:
    """Pad every array in `rows` from n to `bucket` rows.  `replicate=True`
    repeats row 0 (keeps M2L/M2P displacement vectors nonzero, exactly like
    plan.pad_pairs); masks are always zero-padded."""
    out = {}
    for k, a in rows.items():
        if n == bucket:
            out[k] = a
            continue
        pad = np.repeat(a[:1], bucket - n, axis=0) if (replicate and n) else \
            np.zeros((bucket - n,) + a.shape[1:], dtype=a.dtype)
        out[k] = np.concatenate([a, pad], axis=0)
    if "mask" in out and n < bucket:
        out["mask"] = out["mask"].copy()
        out["mask"][n:] = 0.0
    return out


# ------------------------------------------------------------ dataclasses --
@dataclass(frozen=True)
class BatchedUpwardSchedule:
    """Stacked P2M/M2M index tables for a list of trees (None = empty)."""
    n_parts: int
    n_cells_max: int             # power-of-two cell envelope per partition
    n_bodies_max: int            # power-of-two body envelope per partition
    tables: dict = field(repr=False)   # stacked np arrays, keys below

    # tables: leaves (P,Bl) i64 · leaf_mask (P,Bl) f32 · leaf_centers
    # (P,Bl,3) f32 · leaf_idx (P,Bl,W) i64 · leaf_valid (P,Bl,W) bool ·
    # up_ids/up_parents (P,L,Bv) i64 · up_mask (P,L,Bv) f32 · up_d (P,L,Bv,3)
    # f32 · down_* (same shapes, top-aligned)


@dataclass(frozen=True)
class EngineTables:
    """Every frozen table one geometry needs for batched device evaluation."""
    n: int                       # total bodies, original order
    n_parts: int
    n_cells_max: int
    n_bodies_max: int
    p: int                       # expansion order
    up: BatchedUpwardSchedule
    m2l: dict = field(repr=False)        # src/tgt (B,) i64 global cells ·
                                         # mask (B,) f32 · d (B,3) f32
    m2p: dict = field(repr=False)        # b (B,) i64 global cells · mask f32
                                         # · centers (B,3) f32 · t_idx (B,wt)
                                         # i64 global bodies · t_valid bool
    p2p_buckets: tuple = field(repr=False)  # dicts: t_idx/t_valid/s_idx/
                                         # s_valid/mask, widths per bucket
    l2p_t_idx: np.ndarray = field(repr=False)   # (P,Bl,W) global body ids
    orig_idx: np.ndarray = field(repr=False)    # (N,) original body order
    flat_idx: np.ndarray = field(repr=False)    # (N,) matching flat slots


# --------------------------------------------------------------- builders --
def build_batched_upward(trees, scheds) -> BatchedUpwardSchedule:
    """Stack per-tree `TreeSchedules` into one batched upward schedule."""
    P = len(trees)
    live = [(t, s) for t, s in zip(trees, scheds) if t is not None]
    if not live:
        raise ValueError("build_batched_upward: every partition is empty")
    Cmax = bucket_size(max(s.n_cells for _, s in live))
    Nmax = bucket_size(max(len(t.x) for t, _ in live))
    Bl = bucket_size(max(len(s.leaves) for _, s in live))
    W = max(s.leaf_idx.shape[1] for _, s in live)
    Lmax = max((len(s.levels) for _, s in live), default=0)
    Bv = bucket_size(max((len(ls.ids) for _, s in live for ls in s.levels),
                         default=1))

    def per_part(fn):
        return [None if s is None else fn(s) for s in scheds]

    t = {
        "leaves": _stack(per_part(lambda s: s.leaves), (Bl,), np.int64),
        "leaf_mask": _stack(per_part(lambda s: s.leaf_mask), (Bl,), np.float32),
        "leaf_centers": _stack(per_part(lambda s: s.leaf_centers), (Bl, 3),
                               np.float32),
        "leaf_idx": _stack(per_part(lambda s: s.leaf_idx), (Bl, W), np.int64),
        "leaf_valid": _stack(per_part(lambda s: s.leaf_valid), (Bl, W), bool),
    }
    for name, order in (("up", lambda s: tuple(reversed(s.levels))),
                        ("down", lambda s: s.levels)):
        ids = np.zeros((P, Lmax, Bv), np.int64)
        parents = np.zeros((P, Lmax, Bv), np.int64)
        mask = np.zeros((P, Lmax, Bv), np.float32)
        d = np.zeros((P, Lmax, Bv, 3), np.float32)
        for p, s in enumerate(scheds):
            if s is None:
                continue
            for l, ls in enumerate(order(s)):
                k = len(ls.ids)
                ids[p, l, :k] = ls.ids
                parents[p, l, :k] = ls.parents
                mask[p, l, :k] = ls.mask
                d[p, l, :k] = ls.d
        t[f"{name}_ids"], t[f"{name}_parents"] = ids, parents
        t[f"{name}_mask"], t[f"{name}_d"] = mask, d
    return BatchedUpwardSchedule(n_parts=P, n_cells_max=Cmax,
                                 n_bodies_max=Nmax, tables=t)


def stack_bodies(trees, n_bodies_max: int):
    """Stack the (Morton-sorted) bodies of every tree into the payload pair
    `(x_pad (P, Nmax, 3) f32, q_pad (P, Nmax) f32)`.  This is the ONLY array
    pair that changes across within-slack timesteps: one upload refreshes the
    whole geometry's numeric state."""
    P = len(trees)
    x_pad = np.zeros((P, n_bodies_max, 3), np.float32)
    q_pad = np.zeros((P, n_bodies_max), np.float32)
    for p, t in enumerate(trees):
        if t is None:
            continue
        x_pad[p, :len(t.x)] = t.x
        q_pad[p, :len(t.q)] = t.q
    return x_pad, q_pad


def stack_reference_bodies(geo, tables) -> np.ndarray:
    """Stack the geometry's slack-reference positions `x_ref` into the
    payload envelope `(P, Nmax, 3) f32` through the frozen orig->flat gather
    tables.  Built once per engine (x_ref only changes on rebuild, which
    rebuilds the engine): the frozen device view of this array is one leg of
    the batched step-drift revalidation launch."""
    ref = np.zeros((tables.n_parts * tables.n_bodies_max, 3), np.float32)
    ref[tables.flat_idx] = geo.x_ref[tables.orig_idx]
    return ref.reshape(tables.n_parts, tables.n_bodies_max, 3)


def _let_bookkeeping(let):
    if let.cell_src is None or let.body_src is None:
        raise ValueError(
            "engine tables need LET refresh bookkeeping (cell_src/body_src); "
            "this LET was extracted by the reference path")
    return let.cell_src, let.body_src


def build_p2p_stream_tables(p2p_buckets, block_t: int) -> dict | None:
    """Collapse every P2P width-class bucket into ONE unified tile table for
    the streaming kernel (repro.kernels.p2p_stream).

    The gathered path launches one `pallas_call` + one XLA gather per width
    class; the streaming kernel instead runs ALL classes as one grid of
    target tiles, DMA-ing each tile's source/target slabs from the flat
    payload inside the kernel.  That only works because the bucket gather
    rows are *contiguous runs* of flat body ids (`plan.padded_body_gather`
    emits `body_start + arange`, and LET body translation preserves per-leaf
    runs), so a row reduces to `(start, length)` — one slab DMA instead of a
    per-element gather.  This builder VERIFIES that invariant row by row and
    returns None when any row violates it (the engine then falls back to the
    gathered buckets for that geometry — correctness never depends on the
    fast path).

    Returns a dict of frozen tables (payload-independent, device-memoizable):

      meta     (Ti, 4) int32 — per-tile [src_start, src_len, tgt_start,
               tgt_len]; dead padding tiles carry tgt_len == 0 and are
               pruned inside the kernel (no DMA, no compute).
      out_idx  (Ti, block_t) int64 — flat output slot per target lane
               (dead lanes point at slot 0).
      out_valid (Ti, block_t) bool — lane < tgt_len.

    plus statics: smax (power-of-two max source width, the slab size),
    block_t, n_tiles (== Ti, padded to a bucket_size envelope so geometries
    of one shape class share one compiled program), n_live_tiles, and pad
    (payload zero-padding rows so fixed-size slab DMAs never read past the
    end: max(smax, block_t))."""
    _faults.fire("p2p.stream.tables")
    if not p2p_buckets:
        return None
    metas = []
    smax = 8
    for b in p2p_buckets:
        sv, tv = b["s_valid"], b["t_valid"]
        ws, wt = sv.shape[1], tv.shape[1]
        live = b["mask"] != 0.0
        if not np.all((b["mask"] == 0.0) | (b["mask"] == 1.0)):
            return None              # non-binary mask: gathered path only
        s_len = sv.sum(axis=1).astype(np.int64)
        t_len = tv.sum(axis=1).astype(np.int64)
        col_s = np.arange(ws, dtype=np.int64)
        col_t = np.arange(wt, dtype=np.int64)
        # valid-prefix + contiguous-run invariants (checked on live rows)
        ok = (np.array_equal(sv[live], col_s[None, :] < s_len[live, None])
              and np.array_equal(tv[live], col_t[None, :] < t_len[live, None])
              and np.all(np.where(sv[live],
                                  b["s_idx"][live] - b["s_idx"][live, :1]
                                  == col_s[None, :], True))
              and np.all(np.where(tv[live],
                                  b["t_idx"][live] - b["t_idx"][live, :1]
                                  == col_t[None, :], True)))
        if not ok:
            return None
        smax = max(smax, ws)
        s0 = b["s_idx"][live, 0]
        t0 = b["t_idx"][live, 0]
        sl, tl = s_len[live], t_len[live]
        # tile each row's targets into block_t-lane tiles
        n_t = np.maximum((tl + block_t - 1) // block_t, 1)
        rep = np.repeat(np.arange(len(tl)), n_t)
        k = np.arange(len(rep)) - np.repeat(np.cumsum(n_t) - n_t, n_t)
        metas.append(np.stack([
            s0[rep], sl[rep],
            t0[rep] + k * block_t,
            np.minimum(block_t, tl[rep] - k * block_t)], axis=1))
    meta = (np.concatenate(metas, axis=0) if metas
            else np.zeros((0, 4), np.int64))
    meta = meta[meta[:, 3] > 0]      # rows with zero targets contribute 0
    n_live = len(meta)
    if n_live == 0:
        return None
    ti = bucket_size(n_live)
    meta = np.concatenate(
        [meta, np.zeros((ti - n_live, 4), np.int64)], axis=0)
    if int(meta.max()) + max(smax, block_t) >= np.iinfo(np.int32).max:
        return None                  # flat ids must survive int32 meta
    lane = np.arange(block_t, dtype=np.int64)
    out_valid = lane[None, :] < meta[:, 3:4]
    out_idx = np.where(out_valid, meta[:, 2:3] + lane[None, :], 0)
    return {"meta": meta.astype(np.int32), "out_idx": out_idx,
            "out_valid": out_valid, "smax": int(smax),
            "block_t": int(block_t), "n_tiles": int(ti),
            "n_live_tiles": int(n_live),
            "pad": int(max(smax, block_t))}


def build_engine_tables(geo) -> EngineTables:
    """Freeze every stacked table for one GeometryPlan.

    Payload-independent: only index structure, masks and build-time expansion
    centers/displacements are captured, so within-slack timesteps reuse the
    tables (and their device uploads) unchanged."""
    up = build_batched_upward(geo.trees, geo.scheds)
    P, Cmax, Nmax = up.n_parts, up.n_cells_max, up.n_bodies_max

    m2l_rows = {"src": [], "tgt": [], "mask": [], "d": []}
    m2p_rows = {"b": [], "mask": [], "centers": [], "t_idx": [], "t_valid": []}
    bucket_rows: dict = {}       # (wt, ws) -> row lists

    def add_m2l(inter, tgt_off, src_map):
        n = inter.n_m2l
        if n == 0:
            return
        m2l_rows["tgt"].append(tgt_off + inter.m2l_a[:n])
        m2l_rows["src"].append(src_map(inter.m2l_b[:n]))
        m2l_rows["mask"].append(inter.m2l_mask[:n])
        m2l_rows["d"].append(inter.m2l_d[:n])

    def add_m2p(inter, body_off, src_map):
        n = inter.n_m2p
        if n == 0:
            return
        m2p_rows["b"].append(src_map(inter.m2p_b[:n]))
        m2p_rows["mask"].append(inter.m2p_mask[:n])
        m2p_rows["centers"].append(inter.m2p_centers[:n])
        m2p_rows["t_idx"].append(body_off + inter.m2p_t_idx[:n])
        m2p_rows["t_valid"].append(inter.m2p_t_valid[:n])

    def add_p2p(inter, tgt_body_off, body_map):
        for blk in inter.p2p_blocks:
            n = blk.n
            key = (blk.t_idx.shape[1], blk.s_idx.shape[1])
            rows = bucket_rows.setdefault(
                key, {"t_idx": [], "t_valid": [], "s_idx": [], "s_valid": [],
                      "mask": []})
            rows["t_idx"].append(tgt_body_off + blk.t_idx[:n])
            rows["t_valid"].append(blk.t_valid[:n])
            rows["s_idx"].append(body_map(blk.s_idx[:n], blk.s_valid[:n]))
            rows["s_valid"].append(blk.s_valid[:n])
            rows["mask"].append(blk.mask[:n])

    for j, r in enumerate(geo.receivers):
        if r is None:
            continue
        coff, boff = j * Cmax, j * Nmax
        add_m2l(r.local, coff, lambda b, o=coff: o + b)
        add_p2p(r.local, boff, lambda s, v, o=boff: o + s)
        for rb in r.remote:
            cell_src, body_src = _let_bookkeeping(geo.lets[(rb.sender, j)])
            soff_c, soff_b = rb.sender * Cmax, rb.sender * Nmax
            add_m2l(rb.inter, coff,
                    lambda b, cs=cell_src, o=soff_c: o + cs[b])
            add_m2p(rb.inter, boff,
                    lambda b, cs=cell_src, o=soff_c: o + cs[b])
            # clipped-safe: invalid source slots stay at a masked in-range 0
            add_p2p(rb.inter, boff,
                    lambda s, v, bs=body_src, o=soff_b:
                    np.where(v, o + bs[np.where(v, s, 0)], 0))

    def cat(rows):
        return {k: np.concatenate(v, axis=0) for k, v in rows.items()}

    if m2l_rows["src"]:
        m2l = cat(m2l_rows)
        n = len(m2l["src"])
        m2l = _pad_rows(m2l, n, bucket_size(n), replicate=True)
    else:
        m2l = {"src": np.zeros(0, np.int64), "tgt": np.zeros(0, np.int64),
               "mask": np.zeros(0, np.float32), "d": np.zeros((0, 3), np.float32)}
    if m2p_rows["b"]:
        m2p = cat(m2p_rows)
        n = len(m2p["b"])
        m2p = _pad_rows(m2p, n, bucket_size(n), replicate=True)
    else:
        wt = up.tables["leaf_idx"].shape[2]
        m2p = {"b": np.zeros(0, np.int64), "mask": np.zeros(0, np.float32),
               "centers": np.zeros((0, 3), np.float32),
               "t_idx": np.zeros((0, wt), np.int64),
               "t_valid": np.zeros((0, wt), bool)}
    buckets = []
    for (wt, ws) in sorted(bucket_rows):
        b = cat(bucket_rows[(wt, ws)])
        n = len(b["mask"])
        # zero-padding is safe for P2P (r == 0 guard), no replication needed
        buckets.append(_pad_rows(b, n, bucket_size(n), replicate=False))

    l2p_t_idx = (up.tables["leaf_idx"]
                 + (np.arange(P, dtype=np.int64) * Nmax)[:, None, None])
    orig_chunks, flat_chunks = [], []
    for j, t in enumerate(geo.trees):
        if t is None:
            continue
        orig_chunks.append(geo.owners[j][t.perm])
        flat_chunks.append(j * Nmax + np.arange(len(t.x), dtype=np.int64))
    return EngineTables(
        n=geo.n, n_parts=P, n_cells_max=Cmax, n_bodies_max=Nmax, p=geo.p,
        up=up, m2l=m2l, m2p=m2p, p2p_buckets=tuple(buckets),
        l2p_t_idx=l2p_t_idx,
        orig_idx=np.concatenate(orig_chunks),
        flat_idx=np.concatenate(flat_chunks))
