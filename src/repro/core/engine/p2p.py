"""Bucketed P2P execution: every width class in one launch, Pallas-backed.

`plan.build_p2p_blocks` buckets leaf pairs by power-of-two source width per
(target, source) tree pair; `schedules.build_engine_tables` merges those
blocks ACROSS all (receiver, sender) pairs of the geometry, so one geometry
yields a handful of width classes — each executed as a single batched launch
over global body ids instead of one launch per tree pair per width.

Kernel dispatch: with `use_kernels=True` each bucket routes through the
Pallas kernel (`repro.kernels.ops.p2p_auto`) with a per-(S, n_pairs)
autotuned target block size; otherwise the jnp reference path
(`fmm._p2p_vals`) runs — the CPU/interpret fallback the engine defaults to
off-device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fmm import _p2p_vals, device_hook

__all__ = ["p2p_bucket_vals"]


@jax.jit
def _gather_bucket(x, q, t_idx, s_idx, s_valid):
    """Global-id gathers for one bucket: x (P,N,3), q (P,N) payload."""
    x_flat = x.reshape(-1, 3)
    q_flat = q.reshape(-1)
    xt = x_flat[t_idx]                            # (B, wt, 3)
    xs = x_flat[s_idx]                            # (B, ws, 3)
    qs = jnp.where(s_valid, q_flat[s_idx], 0.0)   # (B, ws)
    return xt, xs, qs


def p2p_bucket_vals(x, q, bucket, use_kernels: bool = False,
                    interpret: bool | None = None, asarray=None,
                    to_host: bool = True):
    """Evaluate one width-class bucket -> (B, wt) f32 masked values.

    `to_host=True` (default) returns a NumPy array for the host f64
    accumulation; `to_host=False` keeps the values device-resident for the
    engine's x64 on-device accumulation (no round-trip)."""
    aa = device_hook(asarray)
    xt, xs, qs = _gather_bucket(x, q, aa(bucket["t_idx"]), aa(bucket["s_idx"]),
                                aa(bucket["s_valid"]))
    if use_kernels:
        from repro.kernels.ops import p2p_auto
        vals = p2p_auto(qs, xs, xt, interpret=interpret) \
            * aa(bucket["mask"])[:, None]
    else:
        vals = _p2p_vals(xt, xs, qs, aa(bucket["mask"]))
    return np.asarray(vals) if to_host else vals
