"""Bucketed P2P execution: every width class in one launch, Pallas-backed.

`plan.build_p2p_blocks` buckets leaf pairs by power-of-two source width per
(target, source) tree pair; `schedules.build_engine_tables` merges those
blocks ACROSS all (receiver, sender) pairs of the geometry, so one geometry
yields a handful of width classes — each executed as a single batched launch
over global body ids instead of one launch per tree pair per width.

Kernel dispatch: with `use_kernels=True` each bucket routes through the
Pallas kernel (`repro.kernels.ops.p2p_auto`) with a per-(S, n_pairs)
autotuned target block size; otherwise the jnp reference path
(`fmm._p2p_vals`) runs — the CPU/interpret fallback the engine defaults to
off-device.

Streaming alternative (`p2p_stream_vals`): ALL width classes as one grid of
target tiles over the unified stream table
(`schedules.build_p2p_stream_tables`), gathering source/target slabs inside
the kernel (`repro.kernels.p2p_stream`) instead of materializing per-bucket
gathered operands in HBM.  `use_kernels=False` runs the same slab math as an
XLA gather program (`p2p_stream_gathered`) — the CPU-fast reference the
interpret-smoke CI gate exercises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fmm import _p2p_vals, device_hook
from repro.resilience import faults as _faults

__all__ = ["p2p_bucket_vals", "p2p_stream_vals", "p2p_stream_gathered",
           "stream_payload"]


@jax.jit
def _gather_bucket(x, q, t_idx, s_idx, s_valid):
    """Global-id gathers for one bucket: x (P,N,3), q (P,N) payload."""
    x_flat = x.reshape(-1, 3)
    q_flat = q.reshape(-1)
    xt = x_flat[t_idx]                            # (B, wt, 3)
    xs = x_flat[s_idx]                            # (B, ws, 3)
    qs = jnp.where(s_valid, q_flat[s_idx], 0.0)   # (B, ws)
    return xt, xs, qs


def p2p_bucket_vals(x, q, bucket, use_kernels: bool = False,
                    interpret: bool | None = None, asarray=None,
                    to_host: bool = True):
    """Evaluate one width-class bucket -> (B, wt) f32 masked values.

    `to_host=True` (default) returns a NumPy array for the host f64
    accumulation; `to_host=False` keeps the values device-resident for the
    engine's x64 on-device accumulation (no round-trip)."""
    aa = device_hook(asarray)
    xt, xs, qs = _gather_bucket(x, q, aa(bucket["t_idx"]), aa(bucket["s_idx"]),
                                aa(bucket["s_valid"]))
    if use_kernels:
        _faults.fire("kernels.p2p.launch")
        from repro.kernels.ops import p2p_auto
        vals = p2p_auto(qs, xs, xt, interpret=interpret) \
            * aa(bucket["mask"])[:, None]
    else:
        vals = _p2p_vals(xt, xs, qs, aa(bucket["mask"]))
    return np.asarray(vals) if to_host else vals


def stream_payload(x, q, pad: int):
    """Flatten the (P, Nmax, ...) payload into the streaming kernel's
    structure-of-arrays slab source: (4, P*Nmax + pad) f32 rows [x; y; z; q],
    zero-padded so fixed-size slab reads never run past the end.  Traceable —
    the fused program builds it in-trace from the donated payload (one
    transpose pass instead of one gather per bucket)."""
    x_flat = x.reshape(-1, 3).astype(jnp.float32)
    q_flat = q.reshape(-1).astype(jnp.float32)
    soa = jnp.concatenate([x_flat.T, q_flat[None, :]], axis=0)
    return jnp.pad(soa, ((0, 0), (0, pad)))


def p2p_stream_gathered(meta, payload, *, block_t: int, smax: int):
    """XLA reference for the streaming kernel: gather the SAME (4, smax) /
    (4, block_t) slabs the kernel DMAs, run the SAME tile expression
    (`stream_tile_phi`).  This is the `use_kernels=False` streaming path —
    on CPU it beats interpret-mode kernel emulation by orders of magnitude
    while keeping the unified one-program-all-width-classes structure."""
    from repro.kernels.p2p_stream import stream_tile_phi
    lane_s = jnp.arange(smax)
    lane_t = jnp.arange(block_t)
    src = payload[:, meta[:, 0:1] + lane_s[None, :]]    # (4, Ti, smax)
    tgt = payload[:, meta[:, 2:3] + lane_t[None, :]]    # (4, Ti, block_t)
    phi = jax.vmap(stream_tile_phi, in_axes=(1, 1, 0))(
        src, tgt, meta[:, 1])
    return jnp.where((meta[:, 3] > 0)[:, None], phi, 0.0)


def p2p_stream_vals(x, q, stream: dict, *, use_kernels: bool,
                    interpret: bool | None = None, asarray=None,
                    n_buffers: int = 2):
    """Evaluate the unified stream table -> (Ti, block_t) f32 device values
    (mask semantics live in the table's `out_valid`; lanes past a tile's
    target count are garbage exactly as in the gathered kernel and must be
    dropped by the caller's accumulation)."""
    aa = device_hook(asarray)
    payload = stream_payload(x, q, stream["pad"])
    meta = aa(stream["meta"])
    if use_kernels:
        _faults.fire("kernels.p2p.launch")
        from repro.kernels import ops as kops
        from repro.kernels.p2p_stream import p2p_stream
        interp = kops.INTERPRET if interpret is None else bool(interpret)
        return p2p_stream(meta, payload, block_t=stream["block_t"],
                          smax=stream["smax"], n_buffers=n_buffers,
                          interpret=interp)
    return p2p_stream_gathered(meta, payload, block_t=stream["block_t"],
                               smax=stream["smax"])
