"""Batched multi-tree upward pass: P2M + M2M for every partition in one
jitted launch.

The reference path (fmm.upward_pass) runs one P2M scatter plus one M2M
scatter per level *per tree*, driven by a Python loop over partitions — a
host round-trip per launch.  Here the stacked tables of
`schedules.build_batched_upward` drive a single `jax.vmap` over the
partition axis: per-partition arithmetic is the *same traced closure*
(`ops.p2m_v` / `ops.m2m_v`) the reference kernels use, so the result is
bitwise-identical per partition — padding rows gather in-range slot 0 and
contribute exactly 0 through their masks.

Level slots are bottom-aligned (slot 0 = each tree's own deepest level), so
M2M always runs children-before-parents even when partition depths differ.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.fmm import device_hook

__all__ = ["batched_upward_kernel", "batched_upward"]


@partial(jax.jit, static_argnums=(0,), static_argnames=("n_cells",))
def batched_upward_kernel(ops, x, q, leaves, leaf_mask, leaf_centers,
                          leaf_idx, leaf_valid, up_ids, up_parents, up_mask,
                          up_d, n_cells):
    """x (P,N,3) f32, q (P,N) f32 + stacked tables -> M (P, n_cells, nk)."""
    def p2m_one(xp, qp, lf, lm, lc, li, lv):
        xi = xp[li]                              # (Bl, W, 3)
        qi = jnp.where(lv, qp[li], 0.0)
        M_leaf = ops.p2m_v(qi, xi, lc) * lm[:, None]
        return jnp.zeros((n_cells, ops.nk), jnp.float32).at[lf].add(M_leaf)

    M = jax.vmap(p2m_one)(x, q, leaves, leaf_mask, leaf_centers,
                          leaf_idx, leaf_valid)

    def m2m_one(Mp, ids, parents, mask, d):
        contrib = ops.m2m_v(Mp[ids], d) * mask[:, None]
        return Mp.at[parents].add(contrib)

    for lvl in range(up_ids.shape[1]):           # slot 0 = deepest level
        M = jax.vmap(m2m_one)(M, up_ids[:, lvl], up_parents[:, lvl],
                              up_mask[:, lvl], up_d[:, lvl])
    return M


def batched_upward(ops, x_pad, q_pad, sched, asarray=None) -> jnp.ndarray:
    """Run the batched upward pass from a `BatchedUpwardSchedule` and stacked
    payload (`schedules.stack_bodies`). -> (P, n_cells_max, nk) device array."""
    aa = device_hook(asarray)
    t = sched.tables
    return batched_upward_kernel(
        ops, aa(x_pad, jnp.float32), aa(q_pad, jnp.float32),
        aa(t["leaves"]), aa(t["leaf_mask"]), aa(t["leaf_centers"]),
        aa(t["leaf_idx"]), aa(t["leaf_valid"]),
        aa(t["up_ids"]), aa(t["up_parents"]), aa(t["up_mask"]), aa(t["up_d"]),
        n_cells=sched.n_cells_max)
