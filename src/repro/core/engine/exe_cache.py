"""AOT executable cache: compile once per *shape class*, serve forever.

The fused engine (repro.core.engine.fused) lowers one donated XLA program
per geometry — by far the dominant serving cost for a NEW geometry at
small-to-medium N is that compilation, not the FLOPs.  But the compiled
program depends only on the geometry's *shape class* (padded table dims,
n_parts, table dtypes / the x64 flag, kernel-dispatch statics, backend),
never on table *values*: every index table is a runtime argument.  Two
geometries with equal shape-class keys lower to byte-identical programs, so
the second one can skip XLA entirely.

This module is that cache: `jax.jit(...).lower(...).compile()` products
keyed by the shape-class key (see `fused.executable_key` — it folds in
`schedules.shape_class_digest`), bounded by an LRU, with hit/miss/eviction
counters surfaced on `FMMSession.exe_cache_stats`:

  - `misses` counts actual XLA compilations — the "zero recompile per shape
    class" acceptance tests pin it;
  - `hits` counts engines served an already-compiled executable;
  - every `CompiledEntry` carries a `calls` launch counter and the compiled
    module's HLO text, which is what `analysis.hlo_walk.count_entry_launches`
    pins the one-launch-per-evaluate guarantee against.

The default process-wide cache (`GLOBAL_CACHE`) is deliberately shared
across sessions: a serving fleet holding many tenants' `FMMSession`s pays
one compile per shape class *for the whole process*, which is the
multi-tenant story ROADMAP's FMM-as-a-service item builds on.  Pass a
private `ExecutableCache` for isolated counters (benchmarks, tests).
"""
from __future__ import annotations

from collections import OrderedDict

from repro import obs
from repro.resilience import fallback as _fb
from repro.resilience import faults as _faults

__all__ = ["CompiledEntry", "ExecutableCache", "GLOBAL_CACHE",
           "resolve_cache", "DEFAULT_MAXSIZE"]

DEFAULT_MAXSIZE = 32


class CompiledEntry:
    """One cached executable: the `Compiled` object plus its launch counter
    and (lazily rendered) HLO text for launch-count pinning."""

    __slots__ = ("key", "compiled", "calls", "_hlo")

    def __init__(self, key, compiled):
        self.key = key
        self.compiled = compiled
        self.calls = 0
        self._hlo = None

    @property
    def hlo_text(self) -> str:
        """Post-compilation HLO of this executable (one ENTRY computation —
        `hlo_walk.count_entry_launches` counts exactly that)."""
        if self._hlo is None:
            self._hlo = self.compiled.as_text()
        return self._hlo

    def __call__(self, *args):
        self.calls += 1
        return self.compiled(*args)


class ExecutableCache:
    """LRU-bounded map: shape-class key -> `CompiledEntry`.

    `get_or_compile` is the only population path, so `misses` is exactly
    the number of XLA compilations this cache ever triggered.  Eviction
    drops the least-recently-*resolved* entry (engines resolve their entry
    once per lifetime, then hold a direct reference — an evicted entry keeps
    working for engines already holding it; only *new* engines recompile).
    """

    def __init__(self, maxsize: int = DEFAULT_MAXSIZE):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_compile(self, key, compile_fn,
                       retry: _fb.RetryPolicy | None = None) -> CompiledEntry:
        """Serve the executable for `key`, compiling via `compile_fn()` (->
        a `jax.stages.Compiled`) on first sight of the shape class.

        Failure semantics: a failed compile inserts NOTHING — the cache is
        never poisoned by a partial entry, and the next call retries from
        scratch.  TRANSIENT compile errors (a flaky backend; exceptions
        carrying `transient=True`, e.g. injected ones) are retried in place
        with deterministic backoff (`resilience.fallback.call_with_retry`)
        before propagating; deterministic errors propagate on first sight."""
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            obs.counter_add("exe_cache.hits")
            self._entries.move_to_end(key)
            return entry
        self.misses += 1
        obs.counter_add("exe_cache.misses")

        def attempt():
            _faults.fire("exe_cache.compile")
            return compile_fn()

        # the compile-vs-execute split: every XLA compilation this process
        # ever pays appears as one of these spans; entry launches (`calls`)
        # are the execute side
        with obs.span("exe_cache.compile",
                      {"key": str(key)} if obs.enabled() else None):
            entry = CompiledEntry(key, _fb.call_with_retry(
                attempt, site="exe_cache.compile", policy=retry))
        self._entries[key] = entry
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self.evictions += 1
            obs.counter_add("exe_cache.evictions")
        return entry

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "size": len(self._entries),
                "maxsize": self.maxsize}

    def clear(self) -> None:
        self._entries.clear()

    def keys(self):
        return list(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries


# Process-wide default: one compile per shape class per process, shared by
# every session/engine that doesn't bring its own cache.
GLOBAL_CACHE = ExecutableCache()


def resolve_cache(cache: ExecutableCache | None) -> ExecutableCache:
    return GLOBAL_CACHE if cache is None else cache
