"""Multi-partition FMM: hybrid partitioning + local trees + LET exchange
under any of the four protocols (§2-§4 end to end).

This is the host-level (NumPy index plumbing + JAX arithmetic) executor used
for correctness and for the paper's communication accounting.  The device-
level collective expression of the same schedules lives in collectives.py and
launch/dryrun.py.

The pipeline follows the plan/execute split (repro.core.plan):
`build_distributed_plan` does all host-side geometry once — partitioning,
local trees, sender-side batched LET extraction (`extract_lets`, all P−1
boxes per sender in one pass), protocol scheduling, and the per-receiver
interaction plans against every grafted subtree.  `execute_distributed_plan`
then runs kernels + gathers only, so the same `DistributedPlan` can be
evaluated repeatedly (time-stepping, protocol sweeps) with zero traversal,
list construction or padding work.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import protocols as proto
from repro.core.fmm import (direct_potential, downward_pass, l2p_pass,
                            m2l_apply, m2p_apply, p2p_apply, upward_pass)
from repro.core.hsdx import adjacency_from_boxes, graph_diameter
from repro.core.let import LETData, extract_lets, graft
from repro.core.multipole import get_operators
from repro.core.partition.hot import hot_partition
from repro.core.partition.orb import orb_partition
from repro.core.plan import (InteractionPlan, TreeSchedules,
                             build_interaction_plan, build_tree_schedules)
from repro.core.tree import build_tree

__all__ = ["DistributedFMM", "DistributedPlan", "build_distributed_plan",
           "execute_distributed_plan", "run_distributed_fmm"]

# default eps-inflation of SFC partitions' tight boxes when deriving the
# adjacency graph (fraction of the global span); ORB regions share split
# planes exactly and need no inflation
DEFAULT_SFC_BOX_INFLATION = 0.03


@dataclass
class DistributedFMM:
    phi: np.ndarray                      # potential, original body order
    bytes_matrix: np.ndarray             # (P, P) LET bytes i -> j
    schedule_stats: dict
    loggp_time: float
    partition_stats: dict
    n_stages: int
    adjacency_degree: float
    diameter: int


@dataclass
class _ReceiverPlan:
    """One partition's frozen receiver-side geometry."""
    tree: object
    sched: TreeSchedules
    local: InteractionPlan                       # own tree vs own tree
    remote: list                                 # [(sender, graft, InteractionPlan)]


@dataclass
class DistributedPlan:
    """Everything `execute_distributed_plan` needs — built once, run many."""
    n: int
    nparts: int
    theta: float
    p: int
    part: np.ndarray
    owners: list
    boxes: np.ndarray
    adj_boxes: np.ndarray
    trees: list
    Ms: list                                     # per-partition multipoles (np)
    lets: dict                                   # (i, j) -> LETData
    receivers: list                              # _ReceiverPlan per partition
    bytes_matrix: np.ndarray
    schedule_stats: dict
    loggp_time: float
    n_stages: int
    adjacency_degree: float
    diameter: int
    partition_stats: dict = field(default_factory=dict)


def _partition(x, nparts, method,
               sfc_box_inflation: float = DEFAULT_SFC_BOX_INFLATION):
    """Returns (part, tight_boxes, adjacency_boxes).  ORB regions share split
    planes exactly; SFC partitions fall back to eps-inflated tight boxes."""
    if method == "orb":
        part, tight, regions = orb_partition(x, nparts, regions=True)
        return part, tight, regions
    if method in ("hilbert", "morton"):
        part, _ = hot_partition(x, nparts, curve=method)
        boxes = np.zeros((nparts, 2, 3))
        for p in range(nparts):
            pts = x[part == p]
            if len(pts):
                boxes[p, 0], boxes[p, 1] = pts.min(axis=0), pts.max(axis=0)
        span = (x.max(axis=0) - x.min(axis=0)).max()
        infl = boxes.copy()
        infl[:, 0] -= sfc_box_inflation * span
        infl[:, 1] += sfc_box_inflation * span
        return part, boxes, infl
    raise ValueError(method)


def build_distributed_plan(x, q, nparts: int = 8, method: str = "orb",
                           protocol: str = "hsdx", theta: float = 0.5,
                           ncrit: int = 64, p: int = 4,
                           grain_bytes: int | None = None,
                           check_delivery: bool = True,
                           sfc_box_inflation: float = DEFAULT_SFC_BOX_INFLATION,
                           ) -> DistributedPlan:
    """All host-side geometry + communication metadata, precomputed once."""
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    n = len(x)
    part, boxes, adj_boxes = _partition(x, nparts, method,
                                        sfc_box_inflation=sfc_box_inflation)
    ops = get_operators(p)

    # --- completely local trees (local bounding box, tight cells; §3) ------
    trees, Ms, owners, scheds = [], [], [], []
    for pid in range(nparts):
        idx = np.nonzero(part == pid)[0]
        owners.append(idx)
        t = build_tree(x[idx], q[idx], ncrit=ncrit)
        trees.append(t)
        scheds.append(build_tree_schedules(t))
        Ms.append(np.asarray(upward_pass(t, ops, sched=scheds[-1])))

    # --- sender-initiated LET extraction: all P-1 boxes per sender in one
    #     batched frontier pass -------------------------------------------
    lets: dict[tuple[int, int], LETData] = {}
    B = np.zeros((nparts, nparts), dtype=np.int64)
    for i in range(nparts):
        others = np.array([j for j in range(nparts) if j != i], dtype=np.int64)
        for j, let in zip(others, extract_lets(trees[i], Ms[i],
                                               boxes[others, 0],
                                               boxes[others, 1], theta)):
            lets[(i, int(j))] = let
            B[i, j] = let.nbytes

    # --- protocol schedule + delivery check --------------------------------
    sched = proto.make_schedule(protocol, B, boxes=adj_boxes)
    if check_delivery:
        delivered = proto.simulate_delivery(sched)
        expect = {(i, j): int(B[i, j]) for i in range(nparts)
                  for j in range(nparts) if i != j and B[i, j] > 0}
        assert delivered == expect, f"{protocol} failed to deliver the LET"
    stats = proto.schedule_stats(sched)
    t_model = proto.loggp_time(sched, grain_bytes=grain_bytes)

    # --- receiver side: graft + traverse ONCE into frozen plans ------------
    receivers = []
    for j in range(nparts):
        t = trees[j]
        local = build_interaction_plan(t, t, theta)
        remote = []
        for i in range(nparts):
            if i == j:
                continue
            g = graft(lets[(i, j)])
            remote.append((i, g, build_interaction_plan(t, g, theta,
                                                        with_m2p=True)))
        receivers.append(_ReceiverPlan(tree=t, sched=scheds[j], local=local,
                                       remote=remote))

    adj = adjacency_from_boxes(adj_boxes)
    deg = float(np.max([len(a) for a in adj]))
    return DistributedPlan(
        n=n, nparts=nparts, theta=theta, p=p, part=part, owners=owners,
        boxes=boxes, adj_boxes=adj_boxes, trees=trees, Ms=Ms, lets=lets,
        receivers=receivers, bytes_matrix=B, schedule_stats=stats,
        loggp_time=t_model, n_stages=sched.n_stages, adjacency_degree=deg,
        diameter=graph_diameter(adj),
        partition_stats=dict(nparts=nparts, method=method),
    )


def execute_distributed_plan(plan: DistributedPlan,
                             use_pallas: bool = False) -> np.ndarray:
    """Kernels + gathers only: no traversal, no list building, no padding."""
    ops = get_operators(plan.p)
    phi = np.zeros(plan.n)
    for j in range(plan.nparts):
        r = plan.receivers[j]
        t = r.tree
        L = m2l_apply(ops, jnp.asarray(plan.Ms[j]), r.local)
        phi_local = p2p_apply(t, t, r.local, use_pallas=use_pallas)
        for i, g, inter in r.remote:
            if inter.n_m2l:
                L = L + m2l_apply(ops, jnp.asarray(g.M, dtype=L.dtype), inter)
            if inter.n_p2p:
                phi_local += p2p_apply(t, g, inter, use_pallas=use_pallas)
            if inter.n_m2p:
                phi_local += m2p_apply(t, g.M, inter, p=plan.p)
        L = downward_pass(t, ops, L, sched=r.sched)
        phi_local += l2p_pass(t, ops, L, sched=r.sched)
        phi[plan.owners[j][t.perm]] = phi_local
    return phi


def run_distributed_fmm(x, q, nparts: int = 8, method: str = "orb",
                        protocol: str = "hsdx", theta: float = 0.5,
                        ncrit: int = 64, p: int = 4,
                        grain_bytes: int | None = None,
                        check_delivery: bool = True,
                        sfc_box_inflation: float = DEFAULT_SFC_BOX_INFLATION,
                        ) -> DistributedFMM:
    plan = build_distributed_plan(
        x, q, nparts=nparts, method=method, protocol=protocol, theta=theta,
        ncrit=ncrit, p=p, grain_bytes=grain_bytes,
        check_delivery=check_delivery, sfc_box_inflation=sfc_box_inflation)
    phi = execute_distributed_plan(plan)
    return DistributedFMM(
        phi=phi, bytes_matrix=plan.bytes_matrix,
        schedule_stats=plan.schedule_stats, loggp_time=plan.loggp_time,
        partition_stats=plan.partition_stats, n_stages=plan.n_stages,
        adjacency_degree=plan.adjacency_degree, diameter=plan.diameter,
    )
