"""Legacy multi-partition FMM entry points — thin shims over repro.core.api.

The paper's pipeline now lives in three composable layers (see
repro.core.api): `plan_geometry` (partitioning + local trees + batched LET
extraction + receiver interaction plans, protocol-free), `schedule_comm`
(cheap pure protocol scheduling over the frozen bytes matrix) and
`FMMSession` (memoized device-resident execution, protocol sweeps, and
MAC-slack timestep revalidation).

`run_distributed_fmm` and `build_distributed_plan` are retained as
*deprecated* shims that compose those layers exactly as the monolithic
implementation did — golden tests pin them byte-identical to the new path.
Each warns `DeprecationWarning` exactly once per process.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.core import api
from repro.core.api import (DEFAULT_SFC_BOX_INFLATION, PartitionSpec,
                            execute_geometry)

__all__ = ["DistributedFMM", "DistributedPlan", "build_distributed_plan",
           "execute_distributed_plan", "run_distributed_fmm",
           "DEFAULT_SFC_BOX_INFLATION"]

_DEPRECATION_WARNED: set = set()


def _warn_once(name: str, replacement: str) -> None:
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} from repro.core.api "
        "(one GeometryPlan serves all protocols and timesteps)",
        DeprecationWarning, stacklevel=3)


@dataclass
class DistributedFMM:
    phi: np.ndarray                      # potential, original body order
    bytes_matrix: np.ndarray             # (P, P) LET bytes i -> j
    schedule_stats: dict
    loggp_time: float
    partition_stats: dict
    n_stages: int
    adjacency_degree: float
    diameter: int


@dataclass
class DistributedPlan:
    """Legacy fused plan: one GeometryPlan + one CommSchedule flattened into
    the pre-layering shape `execute_distributed_plan` consumes."""
    n: int
    nparts: int
    theta: float
    p: int
    part: np.ndarray
    owners: list
    boxes: np.ndarray
    adj_boxes: np.ndarray
    trees: list
    Ms: list                                     # per-partition multipoles (np)
    lets: dict                                   # (i, j) -> LETData
    receivers: list                              # api.ReceiverPlan per partition
    bytes_matrix: np.ndarray
    schedule_stats: dict
    loggp_time: float
    n_stages: int
    adjacency_degree: float
    diameter: int
    partition_stats: dict = field(default_factory=dict)


def _spec(nparts, method, theta, ncrit, p, sfc_box_inflation) -> PartitionSpec:
    return PartitionSpec(nparts=nparts, method=method, theta=theta,
                         ncrit=ncrit, p=p,
                         sfc_box_inflation=sfc_box_inflation)


def build_distributed_plan(x, q, nparts: int = 8, method: str = "orb",
                           protocol: str = "hsdx", theta: float = 0.5,
                           ncrit: int = 64, p: int = 4,
                           grain_bytes: int | None = None,
                           check_delivery: bool = True,
                           sfc_box_inflation: float = DEFAULT_SFC_BOX_INFLATION,
                           ) -> DistributedPlan:
    """Deprecated: `api.plan_geometry` + `api.schedule_comm` compose the same
    artifacts without fusing the protocol into the geometry."""
    _warn_once("build_distributed_plan", "plan_geometry/schedule_comm")
    geo = api.plan_geometry(
        x, q, _spec(nparts, method, theta, ncrit, p, sfc_box_inflation))
    cs = api.schedule_comm(geo, protocol, grain_bytes=grain_bytes,
                           check_delivery=check_delivery)
    return DistributedPlan(
        n=geo.n, nparts=geo.nparts, theta=geo.theta, p=geo.p, part=geo.part,
        owners=geo.owners, boxes=geo.boxes, adj_boxes=geo.adj_boxes,
        trees=geo.trees, Ms=geo.Ms, lets=geo.lets, receivers=geo.receivers,
        bytes_matrix=geo.bytes_matrix, schedule_stats=cs.stats,
        loggp_time=cs.loggp_time, n_stages=cs.n_stages,
        adjacency_degree=geo.adjacency_degree, diameter=geo.diameter,
        partition_stats=geo.partition_stats,
    )


def execute_distributed_plan(plan: DistributedPlan,
                             use_pallas: bool = False) -> np.ndarray:
    """Kernels + gathers only: no traversal, no list building, no padding."""
    return execute_geometry(plan, use_kernels=use_pallas)


def run_distributed_fmm(x, q, nparts: int = 8, method: str = "orb",
                        protocol: str = "hsdx", theta: float = 0.5,
                        ncrit: int = 64, p: int = 4,
                        grain_bytes: int | None = None,
                        check_delivery: bool = True,
                        sfc_box_inflation: float = DEFAULT_SFC_BOX_INFLATION,
                        ) -> DistributedFMM:
    """Deprecated: `api.FMMSession.potentials` evaluates the same pipeline
    with device-view memoization and plan reuse across protocols/timesteps."""
    _warn_once("run_distributed_fmm", "FMMSession.potentials")
    geo = api.plan_geometry(
        x, q, _spec(nparts, method, theta, ncrit, p, sfc_box_inflation))
    cs = api.schedule_comm(geo, protocol, grain_bytes=grain_bytes,
                           check_delivery=check_delivery)
    phi = execute_geometry(geo)
    return DistributedFMM(
        phi=phi, bytes_matrix=geo.bytes_matrix, schedule_stats=cs.stats,
        loggp_time=cs.loggp_time, partition_stats=geo.partition_stats,
        n_stages=cs.n_stages, adjacency_degree=geo.adjacency_degree,
        diameter=geo.diameter,
    )
