"""Multi-partition FMM: hybrid partitioning + local trees + LET exchange
under any of the four protocols (§2-§4 end to end).

This is the host-level (NumPy index plumbing + JAX arithmetic) executor used
for correctness and for the paper's communication accounting.  The device-
level collective expression of the same schedules lives in collectives.py and
launch/dryrun.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import protocols as proto
from repro.core.fmm import (direct_potential, downward_pass, l2p_pass,
                            m2l_pass, m2p_pass, p2p_pass, upward_pass)
from repro.core.hsdx import adjacency_from_boxes, graph_diameter
from repro.core.let import LETData, extract_let, graft
from repro.core.multipole import get_operators
from repro.core.partition.hot import hot_partition
from repro.core.partition.orb import orb_partition
from repro.core.traversal import dual_traversal
from repro.core.tree import build_tree

__all__ = ["DistributedFMM", "run_distributed_fmm"]


@dataclass
class DistributedFMM:
    phi: np.ndarray                      # potential, original body order
    bytes_matrix: np.ndarray             # (P, P) LET bytes i -> j
    schedule_stats: dict
    loggp_time: float
    partition_stats: dict
    n_stages: int
    adjacency_degree: float
    diameter: int


def _partition(x, nparts, method):
    """Returns (part, tight_boxes, adjacency_boxes).  ORB regions share split
    planes exactly; SFC partitions fall back to eps-inflated tight boxes."""
    if method == "orb":
        part, tight, regions = orb_partition(x, nparts, regions=True)
        return part, tight, regions
    if method in ("hilbert", "morton"):
        part, _ = hot_partition(x, nparts, curve=method)
        boxes = np.zeros((nparts, 2, 3))
        for p in range(nparts):
            pts = x[part == p]
            if len(pts):
                boxes[p, 0], boxes[p, 1] = pts.min(axis=0), pts.max(axis=0)
        span = (x.max(axis=0) - x.min(axis=0)).max()
        infl = boxes.copy()
        infl[:, 0] -= 0.03 * span
        infl[:, 1] += 0.03 * span
        return part, boxes, infl
    raise ValueError(method)


def run_distributed_fmm(x, q, nparts: int = 8, method: str = "orb",
                        protocol: str = "hsdx", theta: float = 0.5,
                        ncrit: int = 64, p: int = 4,
                        grain_bytes: int | None = None,
                        check_delivery: bool = True) -> DistributedFMM:
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    n = len(x)
    part, boxes, adj_boxes = _partition(x, nparts, method)
    ops = get_operators(p)

    # --- completely local trees (local bounding box, tight cells; §3) ------
    trees, Ms, owners = [], [], []
    for pid in range(nparts):
        idx = np.nonzero(part == pid)[0]
        owners.append(idx)
        t = build_tree(x[idx], q[idx], ncrit=ncrit)
        trees.append(t)
        Ms.append(np.asarray(upward_pass(t, ops)))

    # --- sender-initiated LET extraction (one per ordered pair) ------------
    lets: dict[tuple[int, int], LETData] = {}
    B = np.zeros((nparts, nparts), dtype=np.int64)
    for i in range(nparts):
        for j in range(nparts):
            if i == j:
                continue
            let = extract_let(trees[i], Ms[i], boxes[j, 0], boxes[j, 1], theta)
            lets[(i, j)] = let
            B[i, j] = let.nbytes

    # --- protocol schedule + delivery check ---------------------------------
    sched = proto.make_schedule(protocol, B, boxes=adj_boxes)
    if check_delivery:
        delivered = proto.simulate_delivery(sched)
        expect = {(i, j): int(B[i, j]) for i in range(nparts)
                  for j in range(nparts) if i != j and B[i, j] > 0}
        assert delivered == expect, f"{protocol} failed to deliver the LET"
    stats = proto.schedule_stats(sched)
    t_model = proto.loggp_time(sched, grain_bytes=grain_bytes)

    # --- receiver side: graft + traverse + evaluate -------------------------
    phi = np.zeros(n)
    for j in range(nparts):
        t = trees[j]
        m2l_pairs, p2p_pairs = dual_traversal(t, t, theta)
        L = m2l_pass(ops, jnp.asarray(Ms[j]), t, t, m2l_pairs)
        phi_local = p2p_pass(t, t, p2p_pairs)
        for i in range(nparts):
            if i == j:
                continue
            g = graft(lets[(i, j)])
            m2l_r, p2p_r, m2p_r = dual_traversal(t, g, theta, with_m2p=True)
            if len(m2l_r):
                L = L + m2l_pass(ops, jnp.asarray(g.M, dtype=L.dtype), t, g, m2l_r)
            if len(p2p_r):
                phi_local += p2p_pass(t, g, p2p_r)
            if len(m2p_r):
                phi_local += m2p_pass(t, g.M, g.center, m2p_r, p=p)
        L = downward_pass(t, ops, L)
        phi_local += l2p_pass(t, ops, L)
        phi[owners[j][t.perm]] = phi_local

    adj = adjacency_from_boxes(adj_boxes)
    deg = float(np.max([len(a) for a in adj]))
    return DistributedFMM(
        phi=phi, bytes_matrix=B, schedule_stats=stats, loggp_time=t_model,
        partition_stats=dict(nparts=nparts, method=method),
        n_stages=sched.n_stages, adjacency_degree=deg,
        diameter=graph_diameter(adj),
    )
