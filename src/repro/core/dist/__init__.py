"""Multi-device exchange engine: the paper's LET protocols on real wires.

The fourth pipeline tier made distributed: `plan_geometry` (host geometry)
-> `schedule_comm` (modeled protocol schedules) -> **dist exchange** (this
package: the modeled schedule executed as `shard_map` collective programs)
-> engine phase kernels per rank.

  layout.py   : one shared pool word space over every inter-rank LET span —
                52 f32 words per cell / 8 per body, so span bytes equal
                `GeometryPlan.bytes_matrix` exactly — plus per-rank
                pack/unpack gather tables;
  programs.py : bulk all_to_all, grain-chunked ppermute rounds, and the
                HSDX relay tree, each built from (and asserted equal to)
                the `protocols.Schedule` the LogGP model costs;
  engine.py   : `ShardedEngine` — the batched engine's stacked envelopes
                sharded over a 1-D mesh, exchange wedged between the upward
                pass and the far field, halo-mapped M2L/M2P/P2P, host f64
                accumulation identical to `DeviceEngine.evaluate`.

Entry points: `launch.mesh.host_device_mesh(n)` for a CPU mesh (CI runs on
`--xla_force_host_platform_device_count=4`), `api.FMMSession(mesh=...)` for
session-level dispatch, `benchmarks/fig8_exchange.py` for measured-vs-LogGP
exchange timings.
"""
from repro.core.dist.engine import ShardedEngine
from repro.core.dist.layout import (CELL_WORDS, BODY_WORDS, WireLayout,
                                    WireTables, build_wire_layout,
                                    build_wire_tables)
from repro.core.dist.programs import (DIST_PROTOCOLS, ExchangeProgram, Round,
                                      apply_exchange, build_exchange_program,
                                      predicted_time, rank_schedule,
                                      round_tables)

__all__ = ["ShardedEngine", "CELL_WORDS", "BODY_WORDS", "WireLayout",
           "WireTables", "build_wire_layout", "build_wire_tables",
           "DIST_PROTOCOLS", "ExchangeProgram", "Round", "apply_exchange",
           "build_exchange_program", "predicted_time", "rank_schedule",
           "round_tables"]
