"""The paper's three LET exchange protocols as real collective programs.

Every program is a sequence of *rounds* over the shared pool word space
(`dist.layout`), each round exactly one device collective:

  bulk  (§4, baseline) : ONE padded `jax.lax.all_to_all` — every rank's
         outgoing spans packed into equal (D, seg) segments;
  grain (§4.1)         : the granularity-tuned variant — D-1 ring offsets,
         each edge's payload chunked into `ceil(words / grain_words)`
         `jax.lax.ppermute` rounds sized by the CommSchedule's grain;
  hsdx  (§4.2)         : hierarchical sparse data exchange — the
         `protocols.make_schedule("hsdx", ...)` relay stages over the
         Lemma-1 rank adjacency, each stage decomposed into partial
         permutations by `hsdx.decompose_rounds` and executed as one
         `ppermute` per round, parking in-flight spans at their canonical
         pool offsets between hops.

Single source of truth: programs are BUILT from the same `protocols.Schedule`
tables the LogGP model costs — at build time each program verifies that the
bytes its collectives actually carry equal `protocols.schedule_edge_bytes`
of its schedule, and that the delivered (origin rank -> dst rank) volume
equals the rank-aggregated `GeometryPlan` bytes matrix.  Tests assert the
same from outside.

`moved_bytes` counts real payload words; `padded_wire_bytes` additionally
counts the padding a fixed-size collective physically moves (each round is
one equal-size buffer per participating rank) — the honest denominator when
comparing measured exchange time against the LogGP prediction.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core import hsdx as hsdx_mod
from repro.core import protocols as proto
from repro.core.dist.layout import WireLayout
from repro.resilience import faults as _faults

__all__ = ["DIST_PROTOCOLS", "Round", "ExchangeProgram",
           "build_exchange_program", "rank_schedule", "round_tables",
           "apply_exchange", "predicted_time"]

DIST_PROTOCOLS = ("bulk", "grain", "hsdx")

# the modeled protocol each exchange program executes: bulk and grain both
# move the direct-send (alltoallv) schedule — grain only re-chunks it — and
# hsdx moves the neighbor-relay schedule
_MODEL_OF = {"bulk": "alltoallv", "grain": "alltoallv", "hsdx": "hsdx"}


@dataclass(frozen=True)
class Round:
    """One device collective: an `all_to_all` of (D, seg) segments or a
    `ppermute` of (cap,) buffers along a static permutation."""
    kind: str                    # "all_to_all" | "ppermute"
    perm: tuple                  # ((src, dst), ...); empty for all_to_all
    send_idx: np.ndarray = field(repr=False)  # a2a: (D, D, seg); pp: (D, cap)
    recv_idx: np.ndarray = field(repr=False)  # same shape; pads -> trash

    @property
    def wire_words(self) -> int:
        """Words this round physically moves, padding included."""
        if self.kind == "all_to_all":
            D, _, seg = self.send_idx.shape
            return D * (D - 1) * seg         # self-segments never hit a wire
        return len(self.perm) * self.send_idx.shape[1]


@dataclass(frozen=True)
class ExchangeProgram:
    protocol: str
    layout: WireLayout
    sched: proto.Schedule        # the rank-level schedule the program executes
    rounds: tuple                # tuple[Round, ...]
    moved_bytes: np.ndarray      # (D, D) real payload bytes per directed edge
    delivered_bytes: np.ndarray  # (D, D) origin->final-dst bytes delivered
    padded_wire_bytes: int       # physical bytes incl. padding, all rounds
    grain_bytes: int | None = None

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def stats(self) -> dict:
        return dict(
            protocol=self.protocol, n_rounds=self.n_rounds,
            moved_bytes=int(self.moved_bytes.sum()),
            delivered_bytes=int(self.delivered_bytes.sum()),
            padded_wire_bytes=int(self.padded_wire_bytes),
            per_rank_sent=self.moved_bytes.sum(axis=1).tolist(),
            per_rank_recv=self.moved_bytes.sum(axis=0).tolist(),
            grain_bytes=self.grain_bytes)


def rank_schedule(layout: WireLayout, protocol: str) -> proto.Schedule:
    """The modeled rank-level schedule an exchange program executes."""
    if protocol not in DIST_PROTOCOLS:
        raise ValueError(f"unknown dist protocol {protocol!r}; "
                         f"expected one of {DIST_PROTOCOLS}")
    return proto.make_schedule(_MODEL_OF[protocol], layout.rank_bytes,
                               boxes=layout.rank_boxes)


def predicted_time(program: ExchangeProgram,
                   prm: proto.LogGPParams | None = None) -> float:
    """LogGP prediction for the schedule this program executes (the grain
    variant charges its chunking through `loggp_time`'s grain knob)."""
    return proto.loggp_time(program.sched, prm=prm,
                            grain_bytes=program.grain_bytes)


def _edge_words(layout: WireLayout, ri: int, rj: int) -> np.ndarray:
    """Pool word indices of everything rank ri originates for rank rj —
    contiguous by construction (layout sorts spans by rank pair)."""
    w = layout.rankpair_words.get((ri, rj), 0)
    if not w:
        return np.zeros(0, dtype=np.int64)
    off = layout.rankpair_off[(ri, rj)]
    return np.arange(off, off + w, dtype=np.int64)


def _bulk(layout: WireLayout) -> tuple:
    D, trash = layout.n_ranks, layout.trash
    seg = max((layout.rankpair_words.get((r, s), 0)
               for r in range(D) for s in range(D) if r != s), default=0)
    moved = np.zeros((D, D), np.int64)
    if seg == 0:
        return (), moved, 0
    send = np.zeros((D, D, seg), np.int64)
    recv = np.full((D, D, seg), trash, np.int64)
    for r in range(D):
        for s in range(D):
            if r == s:
                recv[r, s] = trash
                continue
            words = _edge_words(layout, r, s)
            if len(words):
                # all_to_all: dst s's received block r = src r's segment s
                send[r, s, :len(words)] = words
                recv[s, r, :len(words)] = words
                moved[r, s] = 4 * len(words)
    rnd = Round(kind="all_to_all", perm=(), send_idx=send, recv_idx=recv)
    return (rnd,), moved, 4 * rnd.wire_words


def _grain(layout: WireLayout, grain_bytes: int) -> tuple:
    D, trash = layout.n_ranks, layout.trash
    gw = max(1, int(grain_bytes) // 4)
    rounds = []
    moved = np.zeros((D, D), np.int64)
    padded = 0
    for k in range(1, D):
        perm = tuple((r, (r + k) % D) for r in range(D))
        edge_words = {r: _edge_words(layout, r, (r + k) % D)
                      for r in range(D)}
        maxw = max((len(w) for w in edge_words.values()), default=0)
        if maxw == 0:
            continue
        for c in range(math.ceil(maxw / gw)):
            cap = min(gw, maxw - c * gw)
            send = np.zeros((D, cap), np.int64)
            recv = np.full((D, cap), trash, np.int64)
            for r in range(D):
                chunk = edge_words[r][c * gw:c * gw + cap]
                if len(chunk):
                    send[r, :len(chunk)] = chunk
                    recv[(r + k) % D, :len(chunk)] = chunk
                    moved[r, (r + k) % D] += 4 * len(chunk)
            rnd = Round(kind="ppermute", perm=perm, send_idx=send,
                        recv_idx=recv)
            rounds.append(rnd)
            padded += 4 * rnd.wire_words
    return tuple(rounds), moved, padded


def _hsdx(layout: WireLayout, sched: proto.Schedule) -> tuple:
    """Execute the relay schedule: stages -> partial-permutation rounds.
    Tracks which rank holds which (origin, dst) span set so a relay can
    never forward words it has not yet received (build-time invariant)."""
    D, trash = layout.n_ranks, layout.trash
    held = {r: {(ri, rj) for (ri, rj) in layout.rankpair_words
                if ri == r} for r in range(D)}
    rounds = []
    moved = np.zeros((D, D), np.int64)
    delivered = np.zeros((D, D), np.int64)
    padded = 0
    for stage in sched.stages:
        tmap = {(t.src, t.dst): t for t in stage}
        for rnd_edges in hsdx_mod.decompose_rounds(list(tmap)):
            words = {}
            for (u, v) in rnd_edges:
                t = tmap[(u, v)]
                chunks = []
                for (ro, rd, nb) in t.payloads:
                    if (ro, rd) not in held[u]:
                        raise RuntimeError(
                            f"hsdx program: rank {u} relays span "
                            f"{(ro, rd)} before receiving it")
                    if nb != 4 * layout.rankpair_words[(ro, rd)]:
                        raise RuntimeError(
                            "hsdx program: partial span payloads are not "
                            "supported by the pool layout")
                    chunks.append(_edge_words(layout, ro, rd))
                words[(u, v)] = (np.concatenate(chunks) if chunks
                                 else np.zeros(0, np.int64))
            cap = max((len(w) for w in words.values()), default=0)
            if cap == 0:
                continue
            send = np.zeros((D, cap), np.int64)
            recv = np.full((D, cap), trash, np.int64)
            for (u, v) in rnd_edges:
                w = words[(u, v)]
                send[u, :len(w)] = w
                recv[v, :len(w)] = w
                moved[u, v] += 4 * len(w)
                for (ro, rd, nb) in tmap[(u, v)].payloads:
                    held[v].add((ro, rd))
                    if v == rd:
                        delivered[ro, rd] += nb
            rnd = Round(kind="ppermute", perm=tuple(rnd_edges),
                        send_idx=send, recv_idx=recv)
            rounds.append(rnd)
            padded += 4 * rnd.wire_words
    return tuple(rounds), moved, delivered, padded


def build_exchange_program(layout: WireLayout, protocol: str, *,
                           grain_bytes: int | None = None) -> ExchangeProgram:
    """Build (and self-verify) one protocol's collective program."""
    _faults.fire("dist.build_program")
    sched = rank_schedule(layout, protocol)
    offdiag = layout.rank_bytes.copy()
    np.fill_diagonal(offdiag, 0)
    if protocol == "bulk":
        rounds, moved, padded = _bulk(layout)
        delivered = moved.copy()
    elif protocol == "grain":
        gb = (proto.LogGPParams().eager_limit if grain_bytes is None
              else int(grain_bytes))
        rounds, moved, padded = _grain(layout, gb)
        delivered = moved.copy()
        grain_bytes = gb
    else:
        rounds, moved, delivered, padded = _hsdx(layout, sched)
    # single-source-of-truth invariants: the bytes the collectives carry are
    # exactly the modeled schedule's edge bytes, and every rank receives
    # exactly its slice of the GeometryPlan bytes matrix
    model = proto.schedule_edge_bytes(sched)
    if not np.array_equal(moved, model):
        raise RuntimeError(
            f"{protocol}: program moves {moved.tolist()} but the modeled "
            f"schedule says {model.tolist()}")
    if not np.array_equal(delivered, offdiag):
        raise RuntimeError(
            f"{protocol}: delivered {delivered.tolist()} != bytes matrix "
            f"{offdiag.tolist()}")
    from repro import obs
    if obs.enabled():
        obs.event("dist.program_built",
                  {"protocol": protocol, "n_rounds": len(rounds),
                   "moved_bytes": int(moved.sum()),
                   "delivered_bytes": int(delivered.sum()),
                   "padded_wire_bytes": int(padded)})
    return ExchangeProgram(
        protocol=protocol, layout=layout, sched=sched, rounds=rounds,
        moved_bytes=moved, delivered_bytes=delivered,
        padded_wire_bytes=int(padded), grain_bytes=grain_bytes)


def round_tables(program: ExchangeProgram) -> list:
    """The traced side of the program: int32 gather/scatter tables, one dict
    per round, stacked on the (D,) rank axis for shard_map sharding."""
    return [dict(send=r.send_idx.astype(np.int32),
                 recv=r.recv_idx.astype(np.int32)) for r in program.rounds]


def apply_exchange(pool, program: ExchangeProgram, round_tabs, axis: str):
    """Run the program's rounds over a rank-local pool inside `shard_map`.
    `round_tabs[k]["send"/"recv"]` arrive sharded as (1, ...) — leading rank
    axis squeezed here.  Returns the post-exchange pool."""
    for rnd, tabs in zip(program.rounds, round_tabs):
        send = tabs["send"][0]
        recv = tabs["recv"][0]
        buf = pool[send]
        if rnd.kind == "all_to_all":
            buf = jax.lax.all_to_all(buf, axis, 0, 0)
        else:
            buf = jax.lax.ppermute(buf, axis, rnd.perm)
        pool = pool.at[recv].set(buf)
    return pool
