"""ShardedEngine: the batched FMM engine distributed over a shard_map mesh.

Partitions are grouped into contiguous blocks of `nparts / n_ranks` per mesh
rank; every stacked `(n_parts, ...)` envelope of the single-device engine is
sharded on its leading axis, so each rank runs the *same* phase kernels the
`DeviceEngine` runs — on its own partitions only — with one new step wedged
between the upward pass and the far field:

  1. upward (local)   : `engine.upward.batched_upward_kernel` on the rank's
                        (P_r, ...) slice — bitwise-identical per partition;
  2. pack + EXCHANGE  : gather the dynamic words (multipoles, bodies) of
                        every LET span this rank originates into the shared
                        pool (`dist.layout`), then run one protocol's
                        collective program (`dist.programs`) — bulk
                        all_to_all, grain-chunked ppermute rounds, or the
                        HSDX relay tree;
  3. far field + P2P  : M2L/M2P/P2P tables whose remote sources point into
                        the received *halo* rows (`M_src = [local | halo]`),
                        then the same downward sweep / leaf evaluation.

Each phase returns the engine's padded f32 value tables; the host f64
accumulation is identical to `DeviceEngine.evaluate`'s non-x64 path, which
is what pins phi parity (the acceptance tolerance) across all protocols.

The compute tables differ from `engine.schedules.build_engine_tables` only
in id spaces: targets are rank-local (`j_local * Cmax + c`), co-resident
senders stay direct reads, and off-rank senders index the halo block
appended after the rank's own cells/bodies.  Everything crossing the wire is
f32 words of the frozen LET format, so the bytes each collective carries are
exactly `GeometryPlan.bytes_matrix` aggregated to rank granularity —
asserted at program build time and again in tests.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.dist import programs as prog_mod
from repro.core.dist.layout import build_wire_layout, build_wire_tables
from repro.core.fmm import _p2p_vals
from repro.core.multipole import get_operators

__all__ = ["ShardedEngine"]

# padded-row fills that keep every masked lane finite: a zero displacement /
# coincident target-center pair would send the kernel's 1/r derivatives to
# inf, and inf * 0-mask is NaN
_SAFE_D = np.array([1.0, 0.0, 0.0], np.float32)
_FAR_CENTER = np.array([1e6, 1e6, 1e6], np.float32)


def _pad_rank_rows(rows: dict, cap: int, fills: dict) -> dict:
    out = {}
    n = len(next(iter(rows.values()))) if rows else 0
    for k, a in rows.items():
        if n == cap:
            out[k] = a
            continue
        pad = np.broadcast_to(fills[k], (cap - n,) + a.shape[1:]).astype(
            a.dtype)
        out[k] = np.concatenate([a, pad], axis=0) if n else pad.copy()
    return out


class ShardedEngine:
    """Multi-device evaluation of one `GeometryPlan` over a 1-D mesh.

    Parameters
    ----------
    geometry : api.GeometryPlan (nparts must divide evenly over the mesh)
    mesh : a 1-D `jax.sharding.Mesh` (e.g. `launch.mesh.host_device_mesh`)
    grain_bytes : chunk size of the "grain" protocol's ppermute rounds;
        default the LogGP eager limit (the granularity the paper tunes
        around, Fig 6).
    """

    def __init__(self, geometry, mesh, *, grain_bytes: int | None = None):
        from repro.core.engine.schedules import (build_batched_upward,
                                                 stack_bodies)
        if len(mesh.axis_names) != 1:
            raise ValueError("ShardedEngine needs a 1-D mesh; got axes "
                             f"{mesh.axis_names}")
        self.geo = geometry
        self.mesh = mesh
        self.axis = mesh.axis_names[0]
        self.n_ranks = int(np.prod(mesh.devices.shape))
        self.grain_bytes = grain_bytes
        self._ops = get_operators(geometry.p)

        up = build_batched_upward(geometry.trees, geometry.scheds)
        self.up = up
        P, Cmax, Nmax = up.n_parts, up.n_cells_max, up.n_bodies_max
        self.layout = build_wire_layout(geometry, self.n_ranks)
        self.wire = build_wire_tables(geometry, self.layout,
                                      n_cells_max=Cmax, n_bodies_max=Nmax,
                                      nk=self._ops.nk)
        self._build_compute_tables()
        self._x_pad, self._q_pad = stack_bodies(geometry.trees, Nmax)
        self._programs: dict = {}
        self._fns: dict = {}
        self._ex_fns: dict = {}

    # ------------------------------------------------------------- tables --
    def _build_compute_tables(self) -> None:
        geo, up = self.geo, self.up
        lay, wire = self.layout, self.wire
        D, ppr = lay.n_ranks, lay.parts_per_rank
        P, Cmax, Nmax = up.n_parts, up.n_cells_max, up.n_bodies_max
        nk = self._ops.nk

        m2l_rk = [{"src": [], "tgt": [], "mask": [], "d": []}
                  for _ in range(D)]
        m2p_rk = [{"b": [], "mask": [], "centers": [], "t_idx": [],
                   "t_valid": []} for _ in range(D)]
        buckets_rk: list = [dict() for _ in range(D)]

        def add_m2l(r, inter, tgt_off, src_map):
            n = inter.n_m2l
            if n:
                m2l_rk[r]["tgt"].append(tgt_off + inter.m2l_a[:n])
                m2l_rk[r]["src"].append(src_map(inter.m2l_b[:n]))
                m2l_rk[r]["mask"].append(inter.m2l_mask[:n])
                m2l_rk[r]["d"].append(inter.m2l_d[:n])

        def add_m2p(r, inter, body_off, src_map):
            n = inter.n_m2p
            if n:
                m2p_rk[r]["b"].append(src_map(inter.m2p_b[:n]))
                m2p_rk[r]["mask"].append(inter.m2p_mask[:n])
                m2p_rk[r]["centers"].append(inter.m2p_centers[:n])
                m2p_rk[r]["t_idx"].append(body_off + inter.m2p_t_idx[:n])
                m2p_rk[r]["t_valid"].append(inter.m2p_t_valid[:n])

        def add_p2p(r, inter, tgt_off, s_map):
            for blk in inter.p2p_blocks:
                n = blk.n
                key = (blk.t_idx.shape[1], blk.s_idx.shape[1])
                rows = buckets_rk[r].setdefault(
                    key, {"t_idx": [], "t_valid": [], "s_idx": [],
                          "s_valid": [], "mask": []})
                rows["t_idx"].append(tgt_off + blk.t_idx[:n])
                rows["t_valid"].append(blk.t_valid[:n])
                rows["s_idx"].append(s_map(blk.s_idx[:n], blk.s_valid[:n]))
                rows["s_valid"].append(blk.s_valid[:n])
                rows["mask"].append(blk.mask[:n])

        for j, recv in enumerate(geo.receivers):
            if recv is None:
                continue
            r, jl = j // ppr, j % ppr
            coff, boff = jl * Cmax, jl * Nmax
            add_m2l(r, recv.local, coff, lambda b, o=coff: o + b)
            add_p2p(r, recv.local, boff, lambda s, v, o=boff: o + s)
            for rb in recv.remote:
                i = rb.sender
                let = geo.lets[(i, j)]
                if lay.part_rank[i] == r:
                    # co-resident sender: read its device cells/bodies
                    # directly, exactly like the single-device engine
                    cs, bs = let.cell_src, let.body_src
                    soff_c = (i % ppr) * Cmax
                    soff_b = (i % ppr) * Nmax
                    add_m2l(r, rb.inter, coff,
                            lambda b, cs=cs, o=soff_c: o + cs[b])
                    add_m2p(r, rb.inter, boff,
                            lambda b, cs=cs, o=soff_c: o + cs[b])
                    add_p2p(r, rb.inter, boff,
                            lambda s, v, bs=bs, o=soff_b:
                            np.where(v, o + bs[np.where(v, s, 0)], 0))
                else:
                    # off-rank sender: graft-local ids index the received
                    # halo rows appended after this rank's own block
                    hco = ppr * Cmax + wire.halo_cell_off[(i, j)]
                    hbo = ppr * Nmax + wire.halo_body_off[(i, j)]
                    add_m2l(r, rb.inter, coff, lambda b, o=hco: o + b)
                    add_m2p(r, rb.inter, boff, lambda b, o=hco: o + b)
                    add_p2p(r, rb.inter, boff,
                            lambda s, v, o=hbo: np.where(v, o + s, 0))

        def cat(rows):
            return {k: np.concatenate(v, axis=0) for k, v in rows.items()}

        # ---- m2l: (D, Bm) stacked, NaN-safe padded ------------------------
        m2l_cat = [cat(r) if r["src"] else None for r in m2l_rk]
        m2l_cap = max((len(r["src"]) for r in m2l_cat if r), default=0)
        m2l_fill = {"src": np.int64(0), "tgt": np.int64(0),
                    "mask": np.float32(0.0), "d": _SAFE_D}
        m2l_stk = {k: [] for k in m2l_fill}
        for r in range(D):
            rows = _pad_rank_rows(m2l_cat[r] or {
                "src": np.zeros(0, np.int64), "tgt": np.zeros(0, np.int64),
                "mask": np.zeros(0, np.float32),
                "d": np.zeros((0, 3), np.float32)}, m2l_cap, m2l_fill)
            for k in m2l_stk:
                m2l_stk[k].append(rows[k])
        self.m2l = {k: np.stack(v) for k, v in m2l_stk.items()} \
            if m2l_cap else None

        # ---- m2p: (D, Bf, ...) ------------------------------------------
        wt = up.tables["leaf_idx"].shape[2]
        m2p_cat = [cat(r) if r["b"] else None for r in m2p_rk]
        m2p_cap = max((len(r["b"]) for r in m2p_cat if r), default=0)
        m2p_fill = {"b": np.int64(0), "mask": np.float32(0.0),
                    "centers": _FAR_CENTER, "t_idx": np.int64(0),
                    "t_valid": np.False_}
        m2p_stk = {k: [] for k in m2p_fill}
        for r in range(D):
            rows = _pad_rank_rows(m2p_cat[r] or {
                "b": np.zeros(0, np.int64), "mask": np.zeros(0, np.float32),
                "centers": np.zeros((0, 3), np.float32),
                "t_idx": np.zeros((0, wt), np.int64),
                "t_valid": np.zeros((0, wt), bool)}, m2p_cap, m2p_fill)
            for k in m2p_stk:
                m2p_stk[k].append(rows[k])
        self.m2p = {k: np.stack(v) for k, v in m2p_stk.items()} \
            if m2p_cap else None

        # ---- p2p: globally sorted width classes, rows padded per rank ----
        keys = sorted({k for br in buckets_rk for k in br})
        self.p2p_buckets = []
        for key in keys:
            wt_b, ws_b = key
            fill = {"t_idx": np.int64(0), "t_valid": np.False_,
                    "s_idx": np.int64(0), "s_valid": np.False_,
                    "mask": np.float32(0.0)}
            empty = {"t_idx": np.zeros((0, wt_b), np.int64),
                     "t_valid": np.zeros((0, wt_b), bool),
                     "s_idx": np.zeros((0, ws_b), np.int64),
                     "s_valid": np.zeros((0, ws_b), bool),
                     "mask": np.zeros(0, np.float32)}
            per_rank = [cat(buckets_rk[r][key]) if key in buckets_rk[r]
                        else empty for r in range(D)]
            cap = max(len(p["mask"]) for p in per_rank)
            stk = {k: np.stack([_pad_rank_rows(p, cap, fill)[k]
                                for p in per_rank]) for k in fill}
            self.p2p_buckets.append(stk)

        # ---- host accumulation indices -----------------------------------
        self._l2p_idx = (up.tables["leaf_idx"]
                         + (np.arange(P, dtype=np.int64)
                            * Nmax)[:, None, None])
        self._l2p_valid = up.tables["leaf_valid"]
        rank_body_off = (np.arange(D, dtype=np.int64)
                         * ppr * Nmax)[:, None, None]
        self._bucket_gidx = [b["t_idx"] + rank_body_off
                             for b in self.p2p_buckets]
        self._m2p_gidx = (self.m2p["t_idx"] + rank_body_off
                          if self.m2p is not None else None)
        orig_chunks, flat_chunks = [], []
        for j, t in enumerate(geo.trees):
            if t is None:
                continue
            orig_chunks.append(geo.owners[j][t.perm])
            flat_chunks.append(j * Nmax + np.arange(len(t.x), dtype=np.int64))
        self._orig_idx = np.concatenate(orig_chunks)
        self._flat_idx = np.concatenate(flat_chunks)

        # ---- shard_map input pytrees (int32 on the wire side) ------------
        ut = up.tables
        self._part_tabs = {k: ut[k] for k in
                           ("leaves", "leaf_mask", "leaf_centers", "leaf_idx",
                            "leaf_valid", "up_ids", "up_parents", "up_mask",
                            "up_d", "down_ids", "down_parents", "down_mask",
                            "down_d")}
        rt = {"pool_template": wire.pool_template,
              "pack_src": wire.pack_src, "pack_dst": wire.pack_dst,
              "halo_M_idx": wire.halo_M_idx, "halo_x_idx": wire.halo_x_idx,
              "halo_q_idx": wire.halo_q_idx}
        if self.m2l is not None:
            for k, v in self.m2l.items():
                rt[f"m2l_{k}"] = v
        if self.m2p is not None:
            for k, v in self.m2p.items():
                rt[f"m2p_{k}"] = v
        for bi, b in enumerate(self.p2p_buckets):
            for k, v in b.items():
                rt[f"pb{bi}_{k}"] = v
        self._rank_tabs = rt

    # ----------------------------------------------------------- programs --
    def program(self, protocol: str) -> prog_mod.ExchangeProgram:
        if protocol not in self._programs:
            with obs.span("dist.build_program"):
                self._programs[protocol] = prog_mod.build_exchange_program(
                    self.layout, protocol, grain_bytes=self.grain_bytes)
        return self._programs[protocol]

    def exchange_stats(self, protocol: str) -> dict:
        """Measured wire accounting of one protocol's program plus the LogGP
        prediction for the schedule it executes."""
        p = self.program(protocol)
        s = p.stats()
        s["loggp_time"] = prog_mod.predicted_time(p)
        s["rank_bytes"] = self.layout.rank_bytes.tolist()
        return s

    # ------------------------------------------------------------ program --
    def _shard_fn(self, protocol: str):
        if protocol in self._fns:
            return self._fns[protocol]
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS
        from repro.core.engine.upward import batched_upward_kernel

        ops = self._ops
        program = self.program(protocol)
        axis = self.axis
        Cmax = self.up.n_cells_max
        ppr = self.layout.parts_per_rank
        nk = ops.nk
        n_buckets = len(self.p2p_buckets)
        has_m2l = self.m2l is not None
        has_m2p = self.m2p is not None

        def rank_fn(x, q, pt, rt, rtabs):
            # x (ppr, Nmax, 3) f32, q (ppr, Nmax) f32 — this rank's slice
            M = batched_upward_kernel(
                ops, x, q, pt["leaves"], pt["leaf_mask"], pt["leaf_centers"],
                pt["leaf_idx"], pt["leaf_valid"], pt["up_ids"],
                pt["up_parents"], pt["up_mask"], pt["up_d"], n_cells=Cmax)
            M_flat = M.reshape(ppr * Cmax, nk)
            # pack the dynamic words of every originated span, then exchange
            src_vec = jnp.concatenate([M_flat.reshape(-1), x.reshape(-1),
                                       q.reshape(-1)])
            pool = rt["pool_template"][0]
            pool = pool.at[rt["pack_dst"][0]].set(src_vec[rt["pack_src"][0]])
            pool = prog_mod.apply_exchange(pool, program, rtabs, axis)
            M_halo = pool[rt["halo_M_idx"][0]]
            x_halo = pool[rt["halo_x_idx"][0]]
            q_halo = pool[rt["halo_q_idx"][0]]

            # far field over [local | halo] sources
            M_src = jnp.concatenate([M_flat, M_halo], axis=0)
            L_flat = jnp.zeros((ppr * Cmax, nk), jnp.float32)
            if has_m2l:
                contrib = ops.m2l_v(M_src[rt["m2l_src"][0]],
                                    rt["m2l_d"][0]) \
                    * rt["m2l_mask"][0][:, None]
                L_flat = L_flat.at[rt["m2l_tgt"][0]].add(contrib)
            L = L_flat.reshape(ppr, Cmax, nk)

            def l2l_one(Lp, ids, parents, mask, d):
                return Lp.at[ids].add(ops.l2l_v(Lp[parents], d)
                                      * mask[:, None])

            for lvl in range(pt["down_ids"].shape[1]):
                L = jax.vmap(l2l_one)(L, pt["down_ids"][:, lvl],
                                      pt["down_parents"][:, lvl],
                                      pt["down_mask"][:, lvl],
                                      pt["down_d"][:, lvl])

            def l2p_one(Lp, xp, lf, lm, lc, li):
                return ops.l2p_v(Lp[lf], xp[li], lc) * lm[:, None]

            outs = [jax.vmap(l2p_one)(L, x, pt["leaves"], pt["leaf_mask"],
                                      pt["leaf_centers"], pt["leaf_idx"])]

            x_flat = x.reshape(-1, 3)
            q_flat = q.reshape(-1)
            x_src = jnp.concatenate([x_flat, x_halo], axis=0)
            q_src = jnp.concatenate([q_flat, q_halo], axis=0)
            for bi in range(n_buckets):
                t_idx = rt[f"pb{bi}_t_idx"][0]
                s_idx = rt[f"pb{bi}_s_idx"][0]
                qs = jnp.where(rt[f"pb{bi}_s_valid"][0], q_src[s_idx], 0.0)
                outs.append(_p2p_vals(x_flat[t_idx], x_src[s_idx], qs,
                                      rt[f"pb{bi}_mask"][0]))
            if has_m2p:
                outs.append(ops.m2p_v(M_src[rt["m2p_b"][0]],
                                      x_flat[rt["m2p_t_idx"][0]],
                                      rt["m2p_centers"][0])
                            * rt["m2p_mask"][0][:, None])
            return tuple(outs)

        spec = PS(axis)
        n_outs = 1 + n_buckets + (1 if has_m2p else 0)
        fn = jax.jit(shard_map(
            rank_fn, mesh=self.mesh, in_specs=(spec,) * 5,
            out_specs=(spec,) * n_outs, check_rep=False))
        self._fns[protocol] = fn
        return fn

    # ----------------------------------------------------------- evaluate --
    def evaluate(self, protocol: str = "bulk") -> np.ndarray:
        """Full potential in original body order (float64, host) — the
        rank-sharded phases run under `shard_map`, phi accumulates in host
        f64 exactly like `DeviceEngine.evaluate`'s non-x64 path."""
        with obs.span("dist.evaluate") as sp:
            fn = self._shard_fn(protocol)
            outs = sp.fence(fn(self._x_pad, self._q_pad, self._part_tabs,
                               self._rank_tabs,
                               prog_mod.round_tables(
                                   self.program(protocol))))
            obs.counter_add("dist.evaluations")
            if obs.enabled():
                sp.set({"protocol": protocol, "n_ranks": self.n_ranks})
        up = self.up
        P, Nmax = up.n_parts, up.n_bodies_max
        phi_flat = np.zeros(P * Nmax)
        np.add.at(phi_flat, self._l2p_idx.ravel(),
                  np.where(self._l2p_valid.ravel(),
                           np.asarray(outs[0], np.float64).ravel(), 0.0))
        for gidx, bucket, vals in zip(self._bucket_gidx, self.p2p_buckets,
                                      outs[1:1 + len(self.p2p_buckets)]):
            np.add.at(phi_flat, gidx.ravel(),
                      np.where(bucket["t_valid"].ravel(),
                               np.asarray(vals, np.float64).ravel(), 0.0))
        if self.m2p is not None:
            np.add.at(phi_flat, self._m2p_gidx.ravel(),
                      np.where(self.m2p["t_valid"].ravel(),
                               np.asarray(outs[-1], np.float64).ravel(),
                               0.0))
        phi = np.zeros(self.geo.n)
        phi[self._orig_idx] = phi_flat[self._flat_idx]
        return phi

    def refresh_payload(self, geometry) -> None:
        """Rebind to a same-structure geometry (within-slack step): restack
        the (x, q) payload only.  Multipoles and LET payloads are recomputed
        on device from this payload each evaluation, so — unlike the
        single-device engine — no host-side multipole/LET refresh is ever
        needed here."""
        from repro.core.engine.schedules import stack_bodies
        self.geo = geometry
        self._x_pad, self._q_pad = stack_bodies(geometry.trees,
                                                self.up.n_bodies_max)

    # ------------------------------------------------------- verification --
    def verify_exchange(self, protocol: str = "bulk") -> int:
        """Audit one protocol's wire: run pack + exchange (real upward-pass
        payload, no FMM phases after) returning every rank's pool BOTH
        before and after the collective, then check word-exact on the host
        that each inter-rank span landed at its receiver unchanged —
        `packed[rank(i), off:off+w] == exchanged[rank(j), off:off+w]` for
        every layout pair (i, j).  Raises `ExchangeVerificationError` on the
        first corrupted span (resilient sessions treat that as a dist
        failure and fall back to the single-device engine); returns the
        number of verified spans.  Triggered by `REPRO_VERIFY_EXCHANGE=1`
        once per (protocol, geometry version) via the session."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS
        from repro.core.engine.upward import batched_upward_kernel
        from repro.resilience.fallback import ExchangeVerificationError

        ops = self._ops
        program = self.program(protocol)
        axis = self.axis
        Cmax = self.up.n_cells_max
        ppr = self.layout.parts_per_rank
        nk = ops.nk

        def rank_verify(x, q, pt, rt, rtabs):
            M = batched_upward_kernel(
                ops, x, q, pt["leaves"], pt["leaf_mask"], pt["leaf_centers"],
                pt["leaf_idx"], pt["leaf_valid"], pt["up_ids"],
                pt["up_parents"], pt["up_mask"], pt["up_d"], n_cells=Cmax)
            M_flat = M.reshape(ppr * Cmax, nk)
            src_vec = jnp.concatenate([M_flat.reshape(-1), x.reshape(-1),
                                       q.reshape(-1)])
            pool = rt["pool_template"][0]
            packed = pool.at[rt["pack_dst"][0]].set(src_vec[rt["pack_src"][0]])
            exchanged = prog_mod.apply_exchange(packed, program, rtabs, axis)
            return packed[None], exchanged[None]

        spec = PS(axis)
        fn = jax.jit(shard_map(
            rank_verify, mesh=self.mesh, in_specs=(spec,) * 5,
            out_specs=(spec, spec), check_rep=False))
        with obs.span("dist.verify_exchange"):
            packed, exchanged = fn(self._x_pad, self._q_pad, self._part_tabs,
                                   self._rank_tabs,
                                   prog_mod.round_tables(program))
        packed = np.asarray(packed)
        exchanged = np.asarray(exchanged)
        lay = self.layout
        for (i, j) in lay.pairs:
            off, w = lay.span_off[(i, j)], lay.span_words[(i, j)]
            ri, rj = int(lay.part_rank[i]), int(lay.part_rank[j])
            sent = packed[ri, off:off + w]
            got = exchanged[rj, off:off + w]
            if not np.array_equal(sent, got):
                nbad = int((sent != got).sum())
                raise ExchangeVerificationError(
                    "dist.exchange.verify",
                    f"protocol {protocol!r}: span ({i}, {j}) "
                    f"[rank {ri} -> rank {rj}, {w} words @ {off}] arrived "
                    f"corrupted: {nbad} mismatched words")
        obs.counter_add("dist.exchange.verified")
        return len(lay.pairs)

    # ---------------------------------------------------------- benchmark --
    def _build_exchange_fn(self, program: prog_mod.ExchangeProgram):
        """Jitted shard_map program running ONLY pack + exchange (no FMM
        phases) for an arbitrary `ExchangeProgram` — including single-round
        sub-programs, which is how `measure_exchange(per_round=True)` times
        each collective round in isolation.  Returns `fn()` -> (D,) per-rank
        pool checksums (the reduction defeats dead-code elimination)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS
        axis = self.axis

        def rank_ex(rt, rtabs):
            pool = rt["pool_template"][0]
            pool = prog_mod.apply_exchange(pool, program, rtabs, axis)
            return jnp.sum(pool)[None]

        fn = jax.jit(shard_map(
            rank_ex, mesh=self.mesh, in_specs=(PS(axis),) * 2,
            out_specs=PS(axis), check_rep=False))
        tabs = {"pool_template": self.wire.pool_template}
        rtabs = prog_mod.round_tables(program)
        return lambda: fn(tabs, rtabs)

    def exchange_fn(self, protocol: str):
        """Memoized `_build_exchange_fn` for one protocol's full program —
        what `benchmarks/fig8_exchange.py` times against the LogGP
        prediction."""
        if protocol not in self._ex_fns:
            self._ex_fns[protocol] = self._build_exchange_fn(
                self.program(protocol))
        return self._ex_fns[protocol]

    def measure_exchange(self, protocol: str, *, reps: int = 3,
                         per_round: bool = False) -> dict:
        """Run one protocol's exchange-only program and compare measured
        wall time against its LogGP prediction — the `model_drift` probe
        (ISSUE 8): drift = measured_s / loggp_s, so 1.0 means the analytic
        model still predicts the wire.

        Returns the program's static `stats()` plus measured_s / loggp_s /
        model_drift / reps and a per-round breakdown (kind + wire bytes,
        with measured_s per round when `per_round=True` — each round is
        compiled as its own single-round sub-program)."""
        import dataclasses as _dc
        import time as _time
        p = self.program(protocol)
        fn = self.exchange_fn(protocol)
        jax.block_until_ready(fn())          # warm: compile outside timing
        t0 = _time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        measured = (_time.perf_counter() - t0) / reps
        loggp = prog_mod.predicted_time(p)
        drift = measured / loggp if loggp > 0 else float("inf")
        rounds = [{"kind": r.kind, "wire_bytes": 4 * r.wire_words}
                  for r in p.rounds]
        if per_round:
            for rnd, rec in zip(p.rounds, rounds):
                sub = _dc.replace(p, rounds=(rnd,))
                sub_fn = self._build_exchange_fn(sub)
                jax.block_until_ready(sub_fn())
                rt0 = _time.perf_counter()
                for _ in range(reps):
                    rout = sub_fn()
                jax.block_until_ready(rout)
                rec["measured_s"] = (_time.perf_counter() - rt0) / reps
        st = p.stats()
        st.update(measured_s=measured, loggp_s=loggp, model_drift=drift,
                  reps=reps, rounds=rounds,
                  rank_bytes=self.layout.rank_bytes.tolist())
        obs.observe(f"dist.model_drift.{protocol}", drift)
        if obs.enabled():
            obs.event("dist.exchange_probe",
                      {"protocol": protocol, "measured_s": measured,
                       "loggp_s": loggp, "model_drift": drift,
                       "moved_bytes": int(p.moved_bytes.sum()),
                       "n_rounds": p.n_rounds})
        return st
