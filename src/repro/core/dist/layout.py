"""Wire layout for the multi-device LET exchange: one global word space.

The paper's exchange moves, for every (sender i, receiver j) partition pair,
the frozen-size LET payload `geo.bytes_matrix[i, j]` = `n_cells * CELL_BYTES
+ n_bodies * BODY_BYTES` (repro.core.let).  The device programs ship the same
byte count as f32 *words*:

  cell record : 52 words = 208 B  (center x3, radius, child_start, n_child,
                body_start, n_body, then the nk multipole coefficients,
                zero-padded to the frozen record size)
  body record :  8 words =  32 B  (x x3, q, 4 pad words)

so `span_words[(i, j)] * 4 == bytes_matrix[i, j]` exactly — the measured
wire traffic of the collective programs is directly comparable to (and
asserted equal to) the modeled bytes matrix.

Every inter-rank pair gets a contiguous span in ONE shared word space; each
rank holds a `(total_words + 1,)` f32 *pool* (last slot = scatter trash for
padding).  Because the layout is identical on all ranks, a receiver's
scatter indices equal the sender's gather indices, and HSDX relays can park
in-flight spans at their canonical offsets — no per-hop reindexing.

Intra-rank pairs never touch the wire: the sharded engine reads co-resident
senders' multipoles/bodies directly (same trick the single-device engine
uses for all pairs), so `rank_bytes` has a zero diagonal by construction.
Only the structure of the pool (offsets, frozen header words) lives here;
the dynamic words (multipoles, body coordinates/charges) are packed from the
device payload each evaluation by `dist.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import let as let_mod

__all__ = ["CELL_WORDS", "BODY_WORDS", "CELL_M_WORD", "WireLayout",
           "WireTables", "build_wire_layout", "build_wire_tables"]

CELL_WORDS = let_mod.CELL_BYTES // 4      # 52 f32 words per LET cell
BODY_WORDS = let_mod.BODY_BYTES // 4      # 8 f32 words per LET body
CELL_M_WORD = 8                           # multipoles start after the header


@dataclass(frozen=True)
class WireLayout:
    """Rank grouping + the global word space of all inter-rank LET spans."""
    n_ranks: int
    parts_per_rank: int
    part_rank: np.ndarray        # (P,) owning rank of each partition
    rank_bytes: np.ndarray       # (D, D) int64 inter-rank LET bytes, diag 0
    rank_boxes: np.ndarray       # (D, 2, 3) union adjacency boxes per rank
    pairs: tuple                 # ((i, j), ...) inter-rank partition pairs,
                                 # sorted by (rank_i, rank_j, i, j)
    span_off: dict = field(repr=False)    # (i, j) -> word offset
    span_words: dict = field(repr=False)  # (i, j) -> word count
    rankpair_off: dict = field(repr=False)   # (ri, rj) -> word offset
    rankpair_words: dict = field(repr=False)
    total_words: int = 0

    @property
    def trash(self) -> int:
        """Pool slot that absorbs every padding scatter/gather."""
        return self.total_words


@dataclass(frozen=True)
class WireTables:
    """Per-rank pack / unpack index tables over the shared pool layout.

    All arrays are stacked with a leading (D,) rank axis so `shard_map`
    in_specs shard them like every other engine table; inside the shard the
    leading singleton is squeezed away.
    """
    layout: WireLayout
    pool_template: np.ndarray    # (D, W+1) f32: frozen header words of the
                                 # spans each rank ORIGINATES, zeros elsewhere
    pack_src: np.ndarray         # (D, K) i32 into [M_flat | x_flat | q_flat]
    pack_dst: np.ndarray         # (D, K) i32 into the pool (pad -> trash)
    halo_M_idx: np.ndarray       # (D, HM, nk) i32 pool word gathers
    halo_x_idx: np.ndarray       # (D, HB, 3)
    halo_q_idx: np.ndarray       # (D, HB)
    halo_cell_off: dict = field(repr=False)   # (i, j) -> halo row offset
    halo_body_off: dict = field(repr=False)   # on the RECEIVER's rank
    halo_cells: np.ndarray = field(repr=False)   # (D,) real halo rows
    halo_bodies: np.ndarray = field(repr=False)


def build_wire_layout(geo, n_ranks: int) -> WireLayout:
    """Group partitions into `n_ranks` contiguous blocks and lay out one
    span per inter-rank pair with `bytes_matrix[i, j] > 0`."""
    B = np.asarray(geo.bytes_matrix)
    P = len(B)
    D = int(n_ranks)
    if D < 1 or P % D:
        raise ValueError(
            f"dist engine needs nparts divisible by the mesh size: "
            f"nparts={P}, n_ranks={D}")
    ppr = P // D
    part_rank = np.arange(P, dtype=np.int64) // ppr

    rank_bytes = np.zeros((D, D), dtype=np.int64)
    for i in range(P):
        for j in range(P):
            if part_rank[i] != part_rank[j]:
                rank_bytes[part_rank[i], part_rank[j]] += int(B[i, j])

    # union of the owned partitions' (inflated) adjacency boxes; a rank whose
    # partitions are all empty keeps the lo=+inf / hi=-inf sentinel
    adj = np.asarray(geo.adj_boxes, dtype=np.float64)
    rank_boxes = np.empty((D, 2, 3))
    for r in range(D):
        own = adj[r * ppr:(r + 1) * ppr]
        rank_boxes[r, 0] = own[:, 0].min(axis=0)
        rank_boxes[r, 1] = own[:, 1].max(axis=0)

    pairs = sorted(
        ((i, j) for i in range(P) for j in range(P)
         if B[i, j] > 0 and part_rank[i] != part_rank[j]),
        key=lambda ij: (part_rank[ij[0]], part_rank[ij[1]], ij[0], ij[1]))
    span_off, span_words = {}, {}
    rankpair_off, rankpair_words = {}, {}
    off = 0
    for (i, j) in pairs:
        nb = int(B[i, j])
        if nb % 4:
            raise ValueError(f"LET bytes not word-aligned for pair {(i, j)}")
        rk = (int(part_rank[i]), int(part_rank[j]))
        if rk not in rankpair_off:
            rankpair_off[rk] = off
            rankpair_words[rk] = 0
        span_off[(i, j)] = off
        span_words[(i, j)] = nb // 4
        rankpair_words[rk] += nb // 4
        off += nb // 4
    # spans are sorted by rank pair, so every rank pair's spans are one
    # contiguous word range — what lets the exchange programs address a whole
    # (src rank, dst rank) edge as a single arange
    for rk, w in rankpair_words.items():
        assert w * 4 == rank_bytes[rk[0], rk[1]], "span/rank bytes mismatch"
    return WireLayout(
        n_ranks=D, parts_per_rank=ppr, part_rank=part_rank,
        rank_bytes=rank_bytes, rank_boxes=rank_boxes, pairs=tuple(pairs),
        span_off=span_off, span_words=span_words,
        rankpair_off=rankpair_off, rankpair_words=rankpair_words,
        total_words=off)


def _stack_ragged(chunks, fill, dtype, tail_shape=()):
    """Stack per-rank ragged index arrays into (D, max, *tail), `fill`-pad."""
    D = len(chunks)
    cap = max((len(c) for c in chunks), default=0)
    out = np.full((D, cap) + tail_shape, fill, dtype=dtype)
    for r, c in enumerate(chunks):
        if len(c):
            out[r, :len(c)] = c
    return out


def build_wire_tables(geo, layout: WireLayout, *, n_cells_max: int,
                      n_bodies_max: int, nk: int) -> WireTables:
    """Freeze the pack/unpack tables: pure layout + LET structure, no numeric
    payload (the dynamic words are gathered from the device payload at
    evaluation time)."""
    if CELL_M_WORD + nk > CELL_WORDS:
        raise ValueError(
            f"multipole order too large for the frozen {CELL_WORDS}-word "
            f"cell record: needs {CELL_M_WORD + nk} words (nk={nk}); the "
            f"wire format caps nk at {CELL_WORDS - CELL_M_WORD}")
    D, ppr = layout.n_ranks, layout.parts_per_rank
    Cmax, Nmax = n_cells_max, n_bodies_max
    W = layout.total_words
    trash = layout.trash
    m_total = ppr * Cmax * nk            # per-rank pack-source vector layout:
    x_total = ppr * Nmax * 3             # [M_flat | x_flat | q_flat]

    template = np.zeros((D, W + 1), np.float32)
    pack_src = [[] for _ in range(D)]
    pack_dst = [[] for _ in range(D)]
    for (i, j) in layout.pairs:
        let = geo.lets[(i, j)]
        r = int(layout.part_rank[i])
        il = i % ppr
        off = layout.span_off[(i, j)]
        S, Bn = let.n_cells, len(let.q)
        cbase = off + np.arange(S, dtype=np.int64) * CELL_WORDS
        # frozen header words (structure never changes within a geometry)
        template[r, cbase + 0] = let.center[:, 0]
        template[r, cbase + 1] = let.center[:, 1]
        template[r, cbase + 2] = let.center[:, 2]
        template[r, cbase + 3] = let.radius
        template[r, cbase + 4] = let.child_start
        template[r, cbase + 5] = let.n_child
        template[r, cbase + 6] = let.body_start
        template[r, cbase + 7] = let.n_body
        # dynamic multipole words, gathered from the sender's device M
        k = np.arange(nk, dtype=np.int64)
        pack_dst[r].append((cbase[:, None] + CELL_M_WORD + k).ravel())
        pack_src[r].append(
            (((il * Cmax + let.cell_src)[:, None]) * nk + k).ravel())
        if Bn:
            bbase = off + S * CELL_WORDS + \
                np.arange(Bn, dtype=np.int64) * BODY_WORDS
            ax = np.arange(3, dtype=np.int64)
            pack_dst[r].append((bbase[:, None] + ax).ravel())
            pack_src[r].append(
                (m_total + ((il * Nmax + let.body_src)[:, None]) * 3
                 + ax).ravel())
            pack_dst[r].append(bbase + 3)
            pack_src[r].append(m_total + x_total + il * Nmax + let.body_src)

    def cat(chunks):
        return (np.concatenate(chunks) if chunks
                else np.zeros(0, dtype=np.int64))

    src_chunks = [cat(c) for c in pack_src]
    dst_chunks = [cat(c) for c in pack_dst]
    pack_src_t = _stack_ragged(src_chunks, 0, np.int32)
    pack_dst_t = _stack_ragged(dst_chunks, trash, np.int32)

    # receiver-side halo gathers: for each rank, every inter-rank span it
    # receives, receivers ascending then senders ascending — the same order
    # dist.engine walks when translating graft-local ids to halo rows
    halo_cell_off: dict = {}
    halo_body_off: dict = {}
    hM = [[] for _ in range(D)]
    hx = [[] for _ in range(D)]
    hq = [[] for _ in range(D)]
    halo_cells = np.zeros(D, np.int64)
    halo_bodies = np.zeros(D, np.int64)
    k = np.arange(nk, dtype=np.int64)
    ax = np.arange(3, dtype=np.int64)
    for r in range(D):
        for j in range(r * ppr, (r + 1) * ppr):
            for i in range(len(layout.part_rank)):
                if (i, j) not in layout.span_off:
                    continue
                let = geo.lets[(i, j)]
                off = layout.span_off[(i, j)]
                S, Bn = let.n_cells, len(let.q)
                halo_cell_off[(i, j)] = int(halo_cells[r])
                halo_body_off[(i, j)] = int(halo_bodies[r])
                halo_cells[r] += S
                halo_bodies[r] += Bn
                cbase = off + np.arange(S, dtype=np.int64) * CELL_WORDS
                hM[r].append(cbase[:, None] + CELL_M_WORD + k)
                if Bn:
                    bbase = off + S * CELL_WORDS + \
                        np.arange(Bn, dtype=np.int64) * BODY_WORDS
                    hx[r].append(bbase[:, None] + ax)
                    hq[r].append(bbase + 3)

    def cat2(chunks, tail):
        return (np.concatenate(chunks, axis=0) if chunks
                else np.zeros((0,) + tail, dtype=np.int64))

    halo_M = _stack_ragged([cat2(c, (nk,)) for c in hM], trash, np.int32,
                           (nk,))
    halo_x = _stack_ragged([cat2(c, (3,)) for c in hx], trash, np.int32, (3,))
    halo_q = _stack_ragged([cat(c) for c in hq], trash, np.int32)
    return WireTables(
        layout=layout, pool_template=template,
        pack_src=pack_src_t, pack_dst=pack_dst_t,
        halo_M_idx=halo_M, halo_x_idx=halo_x, halo_q_idx=halo_q,
        halo_cell_off=halo_cell_off, halo_body_off=halo_body_off,
        halo_cells=halo_cells, halo_bodies=halo_bodies)
