"""Adaptive octree with tight (squeezed) cell bounding boxes.

Construction is host-side NumPy — exactly as production FMM codes build trees
and interaction lists on the CPU — emitting static-shape index arrays that the
JAX/Pallas kernels consume.  Cells squeeze their bounding box to the particles
they own (the paper's Fig 1(d)), which is what makes the hybrid-ORB local-tree
scheme competitive: cells are "not aligned in the first place", so partition
misalignment costs nothing extra.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.partition.sfc import morton_encode

__all__ = ["Tree", "build_tree"]


@dataclass
class Tree:
    """Flat adaptive octree. Bodies are stored Morton-sorted; `perm` maps
    sorted position -> original index."""
    x: np.ndarray            # (N, 3) sorted bodies
    q: np.ndarray            # (N,)   sorted charges
    perm: np.ndarray         # (N,)   sorted -> original
    # per-cell arrays (C cells, root = 0)
    parent: np.ndarray       # (C,) int
    child_start: np.ndarray  # (C,) first child cell id (0 if leaf)
    n_child: np.ndarray      # (C,) number of children (0 for leaf)
    body_start: np.ndarray   # (C,) first body (in sorted order)
    n_body: np.ndarray       # (C,)
    center: np.ndarray       # (C, 3) tight bbox center (expansion center)
    radius: np.ndarray       # (C,)   tight half-diagonal
    bbox_min: np.ndarray     # (C, 3) tight
    bbox_max: np.ndarray     # (C, 3)
    level: np.ndarray        # (C,)
    ncrit: int = 64

    @property
    def n_cells(self) -> int:
        return len(self.parent)

    @property
    def is_leaf(self) -> np.ndarray:
        return self.n_child == 0

    @property
    def leaves(self) -> np.ndarray:
        return np.nonzero(self.is_leaf)[0]

    def levels_desc(self):
        """Cell ids grouped by level, deepest first (for the upward pass)."""
        for lvl in range(self.level.max(), -1, -1):
            yield np.nonzero(self.level == lvl)[0]

    def padded_leaf_bodies(self):
        """(n_leaf, ncrit) body indices padded with -1, aligned with .leaves."""
        leaves = self.leaves
        out = -np.ones((len(leaves), self.ncrit), dtype=np.int64)
        for i, c in enumerate(leaves):
            s, n = self.body_start[c], self.n_body[c]
            out[i, :n] = np.arange(s, s + n)
        return out


def build_tree(x: np.ndarray, q: np.ndarray, ncrit: int = 64,
               max_depth: int = 21, bbox=None) -> Tree:
    """Build an adaptive octree over the *local* bounding box (paper §3: the
    tree is completely local — no global Morton key)."""
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    n = len(x)
    if bbox is None:
        lo, hi = x.min(axis=0), x.max(axis=0)
    else:
        lo, hi = np.asarray(bbox[0], dtype=np.float64), np.asarray(bbox[1], dtype=np.float64)
    span = np.maximum((hi - lo).max(), 1e-12)
    # cubic box (slightly inflated) for key generation only
    ctr = (lo + hi) / 2
    lo_cube = ctr - span * 0.5000001
    depth = min(max_depth, 21)
    keys = morton_encode(((x - lo_cube) / (span * 1.0000002) * (1 << depth)).astype(np.uint64), depth)
    order = np.argsort(keys, kind="stable")
    xs, qs, keys = x[order], q[order], keys[order]

    parent, child_start, n_child = [0], [0], [0]
    body_start, n_body, level = [0], [n], [0]
    # recursion over (cell, body range, depth); children appended breadth-last
    stack = [(0, 0, n, 0)]
    while stack:
        cid, s, e, lvl = stack.pop()
        body_start[cid], n_body[cid] = s, e - s
        if e - s <= ncrit or lvl >= depth:
            continue
        # split by the 3-bit Morton digit at this level
        shift = 3 * (depth - lvl - 1)
        digits = (keys[s:e] >> np.uint64(shift)) & np.uint64(7)
        counts = np.bincount(digits.astype(np.int64), minlength=8)
        first_child = len(parent)
        nc = 0
        off = s
        for oct_ in range(8):
            c = counts[oct_]
            if c == 0:
                continue
            parent.append(cid)
            child_start.append(0)
            n_child.append(0)
            body_start.append(off)
            n_body.append(c)
            level.append(lvl + 1)
            stack.append((first_child + nc, off, off + c, lvl + 1))
            nc += 1
            off += c
        child_start[cid], n_child[cid] = first_child, nc

    C = len(parent)
    bmin = np.empty((C, 3))
    bmax = np.empty((C, 3))
    for c in range(C):
        s, nb = body_start[c], n_body[c]
        pts = xs[s:s + nb]
        bmin[c] = pts.min(axis=0)
        bmax[c] = pts.max(axis=0)
    centerc = (bmin + bmax) / 2
    radius = 0.5 * np.linalg.norm(bmax - bmin, axis=1)
    return Tree(
        x=xs, q=qs, perm=order,
        parent=np.asarray(parent, dtype=np.int64),
        child_start=np.asarray(child_start, dtype=np.int64),
        n_child=np.asarray(n_child, dtype=np.int64),
        body_start=np.asarray(body_start, dtype=np.int64),
        n_body=np.asarray(n_body, dtype=np.int64),
        center=centerc, radius=radius, bbox_min=bmin, bbox_max=bmax,
        level=np.asarray(level, dtype=np.int64), ncrit=ncrit,
    )
