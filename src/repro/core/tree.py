"""Adaptive octree with tight (squeezed) cell bounding boxes.

Construction is host-side NumPy — exactly as production FMM codes build trees
and interaction lists on the CPU — emitting static-shape index arrays that the
JAX/Pallas kernels consume.  Cells squeeze their bounding box to the particles
they own (the paper's Fig 1(d)), which is what makes the hybrid-ORB local-tree
scheme competitive: cells are "not aligned in the first place", so partition
misalignment costs nothing extra.

Construction is *level-synchronous* (Hu, Gumerov & Duraiswami style): each
refinement level splits every over-full cell in one batch of array ops
(digit histogram via `np.add.at`, child allocation via `cumsum`), so the only
Python loop is over tree levels, never over cells.  Cell ids come out in BFS
order — levels are contiguous index ranges and children of one parent are
contiguous — which the downstream traversal/plan layers exploit.  Tight
bounding boxes are computed with segment reductions (`np.minimum.reduceat`
over the Morton-sorted leaf ranges, then a level-wise scatter-min/max up the
tree) instead of a per-cell loop.

The seed's per-cell loop construction is retained in
`repro.core.reference.reference_build_tree` and pinned by golden tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.partition.sfc import morton_encode

__all__ = ["Tree", "build_tree", "bucket_size", "flat_cell_tables"]


def bucket_size(n: int, lo: int = 16) -> int:
    """Smallest power-of-two >= n (at least `lo`) — shared JIT cache shapes.
    Lives here (the bottom layer) so both the plan padding and the device
    cell-table padding round with ONE rule; re-exported by plan.py."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclass
class Tree:
    """Flat adaptive octree. Bodies are stored Morton-sorted; `perm` maps
    sorted position -> original index."""
    x: np.ndarray            # (N, 3) sorted bodies
    q: np.ndarray            # (N,)   sorted charges
    perm: np.ndarray         # (N,)   sorted -> original
    # per-cell arrays (C cells, root = 0)
    parent: np.ndarray       # (C,) int
    child_start: np.ndarray  # (C,) first child cell id (0 if leaf)
    n_child: np.ndarray      # (C,) number of children (0 for leaf)
    body_start: np.ndarray   # (C,) first body (in sorted order)
    n_body: np.ndarray       # (C,)
    center: np.ndarray       # (C, 3) tight bbox center (expansion center)
    radius: np.ndarray       # (C,)   tight half-diagonal
    bbox_min: np.ndarray     # (C, 3) tight
    bbox_max: np.ndarray     # (C, 3)
    level: np.ndarray        # (C,)
    ncrit: int = 64

    @property
    def n_cells(self) -> int:
        return len(self.parent)

    @property
    def is_leaf(self) -> np.ndarray:
        return self.n_child == 0

    @property
    def leaves(self) -> np.ndarray:
        return np.nonzero(self.is_leaf)[0]

    def levels_desc(self):
        """Cell ids grouped by level, deepest first (for the upward pass)."""
        for lvl in range(self.level.max(), -1, -1):
            yield np.nonzero(self.level == lvl)[0]

    def device_tables(self, pad_cells: int | None = None) -> dict:
        """Device-friendly flat cell tables (see `flat_cell_tables`)."""
        return flat_cell_tables(self, pad_cells=pad_cells)

    def padded_leaf_bodies(self):
        """(n_leaf, ncrit) body indices padded with -1, aligned with .leaves."""
        leaves = self.leaves
        nb = self.n_body[leaves]
        if int(nb.max(initial=0)) > self.ncrit:
            # depth-capped leaves can exceed ncrit; never truncate silently
            raise ValueError("leaf population exceeds ncrit; use a wider gather")
        col = np.arange(self.ncrit, dtype=np.int64)
        out = self.body_start[leaves, None] + col[None, :]
        return np.where(col[None, :] < nb[:, None], out, -1)


def _morton_sort(x: np.ndarray, q: np.ndarray, max_depth: int = 21, bbox=None):
    """Morton-sort bodies over the *local* bounding box (paper §3: the tree is
    completely local — no global key).  Returns (xs, qs, keys, order, depth)."""
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    if bbox is None:
        lo, hi = x.min(axis=0), x.max(axis=0)
    else:
        lo, hi = np.asarray(bbox[0], dtype=np.float64), np.asarray(bbox[1], dtype=np.float64)
    span = np.maximum((hi - lo).max(), 1e-12)
    # cubic box (slightly inflated) for key generation only
    ctr = (lo + hi) / 2
    lo_cube = ctr - span * 0.5000001
    depth = min(max_depth, 21)
    keys = morton_encode(((x - lo_cube) / (span * 1.0000002) * (1 << depth)).astype(np.uint64), depth)
    order = np.argsort(keys, kind="stable")
    return x[order], q[order], keys[order], order, depth


def _segmented_arange(counts: np.ndarray) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated — the cumsum/repeat idiom."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    return (np.arange(total, dtype=np.int64)
            - np.repeat(np.cumsum(counts) - counts, counts))


def flat_cell_tables(tree, pad_cells: int | None = None) -> dict:
    """Flat per-cell tables the device traversal consumes in one gather each.

    Works for any tree-like object (Tree or a grafted LET view): the MAC
    frontier loop only needs center/radius for scoring, child_start/n_child
    for expansion, and is_leaf/truncated for classification.  Cell counts are
    padded to a power of two (`pad_cells` overrides) so trees of similar size
    share one traced traversal program; padded slots are inert leaves
    (radius 0, no children, never reached by valid frontier entries).

    dtypes are the device convention: f32 geometry, i32 structure — the f64
    host arrays stay the traversal *reference* (core.traversal).
    """
    C = len(np.asarray(tree.radius))
    Cpad = pad_cells or bucket_size(max(C, 1))
    if Cpad < C:
        raise ValueError(f"pad_cells={Cpad} < {C} cells")
    center = np.zeros((Cpad, 3), np.float32)
    radius = np.zeros(Cpad, np.float32)
    child_start = np.zeros(Cpad, np.int32)
    n_child = np.zeros(Cpad, np.int32)
    is_leaf = np.ones(Cpad, bool)
    truncated = np.zeros(Cpad, bool)
    center[:C] = np.asarray(tree.center, np.float32)
    radius[:C] = np.asarray(tree.radius, np.float32)
    child_start[:C] = np.asarray(tree.child_start, np.int32)
    n_child[:C] = np.asarray(tree.n_child, np.int32)
    is_leaf[:C] = np.asarray(tree.is_leaf, bool)
    t = getattr(tree, "truncated", None)
    if t is not None:
        truncated[:C] = np.asarray(t, bool)
    return {"center": center, "radius": radius, "child_start": child_start,
            "n_child": n_child, "is_leaf": is_leaf, "truncated": truncated,
            "n_cells": C}


def build_tree(x: np.ndarray, q: np.ndarray, ncrit: int = 64,
               max_depth: int = 21, bbox=None) -> Tree:
    """Build an adaptive octree with level-synchronous array passes."""
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    n = len(x)
    if n == 0:
        raise ValueError("build_tree requires at least one body")
    xs, qs, keys, order, depth = _morton_sort(x, q, max_depth=max_depth, bbox=bbox)

    # --- structure: split every over-full frontier cell per level ----------
    parent_ch, cstart_ch, nchild_ch, bstart_ch, nbody_ch, level_ch = [], [], [], [], [], []
    f_parent = np.zeros(1, dtype=np.int64)   # seed convention: parent[0] == 0
    f_start = np.zeros(1, dtype=np.int64)
    f_end = np.array([n], dtype=np.int64)
    next_id, lvl = 1, 0
    while len(f_parent):
        k = len(f_parent)
        nb = f_end - f_start
        cs = np.zeros(k, dtype=np.int64)
        nc = np.zeros(k, dtype=np.int64)
        split = (nb > ncrit) & (lvl < depth)
        sidx = np.nonzero(split)[0]
        if len(sidx):
            # 3-bit Morton digit histogram for all bodies of all split cells
            shift = np.uint64(3 * (depth - lvl - 1))
            per_cell = nb[sidx]
            body_idx = np.repeat(f_start[sidx], per_cell) + _segmented_arange(per_cell)
            owner = np.repeat(np.arange(len(sidx)), per_cell)
            digits = ((keys[body_idx] >> shift) & np.uint64(7)).astype(np.int64)
            cnt = np.zeros((len(sidx), 8), dtype=np.int64)
            np.add.at(cnt, (owner, digits), 1)
            childmask = cnt > 0
            nchild = childmask.sum(axis=1)
            nc[sidx] = nchild
            cs[sidx] = next_id + np.cumsum(nchild) - nchild
            # children are contiguous because bodies are Morton-sorted
            off = f_start[sidx, None] + np.cumsum(cnt, axis=1) - cnt
            new_start = off[childmask]
            new_n = cnt[childmask]
            # this level's cells hold ids [next_id - k, next_id)
            this_level_ids = next_id - k + np.arange(k, dtype=np.int64)
            new_parent = np.repeat(this_level_ids[sidx], nchild)
            total_new = int(nchild.sum())
        else:
            new_start = new_n = new_parent = np.zeros(0, dtype=np.int64)
            total_new = 0
        parent_ch.append(f_parent)
        cstart_ch.append(cs)
        nchild_ch.append(nc)
        bstart_ch.append(f_start)
        nbody_ch.append(nb)
        level_ch.append(np.full(k, lvl, dtype=np.int64))
        f_parent, f_start, f_end = new_parent, new_start, new_start + new_n
        next_id += total_new
        lvl += 1

    parent = np.concatenate(parent_ch)
    child_start = np.concatenate(cstart_ch)
    n_child = np.concatenate(nchild_ch)
    body_start = np.concatenate(bstart_ch)
    n_body = np.concatenate(nbody_ch)
    level = np.concatenate(level_ch)
    C = len(parent)

    # --- tight bboxes: segment reductions at leaves, scatter-min/max up ----
    bmin = np.full((C, 3), np.inf)
    bmax = np.full((C, 3), -np.inf)
    leaf_ids = np.nonzero(n_child == 0)[0]
    lorder = np.argsort(body_start[leaf_ids], kind="stable")
    ls = leaf_ids[lorder]
    starts = body_start[ls]  # leaf body ranges partition [0, n): starts[0] == 0
    bmin[ls] = np.minimum.reduceat(xs, starts, axis=0)
    bmax[ls] = np.maximum.reduceat(xs, starts, axis=0)
    for top in range(int(level.max()), 0, -1):
        ids = np.nonzero(level == top)[0]
        np.minimum.at(bmin, parent[ids], bmin[ids])
        np.maximum.at(bmax, parent[ids], bmax[ids])

    centerc = (bmin + bmax) / 2
    radius = 0.5 * np.linalg.norm(bmax - bmin, axis=1)
    return Tree(
        x=xs, q=qs, perm=order,
        parent=parent, child_start=child_start, n_child=n_child,
        body_start=body_start, n_body=n_body,
        center=centerc, radius=radius, bbox_min=bmin, bbox_max=bmax,
        level=level, ncrit=ncrit,
    )
