"""Retained per-element reference implementations (pre-vectorization).

These are the seed repo's pure-Python loop versions of tree construction,
dual traversal, LET extraction and body padding, kept verbatim so the
frontier-vectorized rewrites in `tree.py`, `traversal.py`, `let.py` and
`plan.py` stay pinned by golden-equivalence tests (identical pair sets,
identical LET contents, identical potentials).  They are also what
`benchmarks/host_side.py` measures the vectorized passes against.

Do not optimise this module — its value is being the slow, obviously-correct
baseline.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.let import LETData
from repro.core.tree import Tree, _morton_sort

__all__ = [
    "reference_build_tree",
    "reference_dual_traversal",
    "reference_extract_let",
    "reference_pad_bodies",
    "reference_padded_leaf_bodies",
]


def reference_build_tree(x: np.ndarray, q: np.ndarray, ncrit: int = 64,
                         max_depth: int = 21, bbox=None) -> Tree:
    """Seed `build_tree`: per-cell split stack + per-cell bbox loop."""
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    n = len(x)
    xs, qs, keys, order, depth = _morton_sort(x, q, max_depth=max_depth, bbox=bbox)

    parent, child_start, n_child = [0], [0], [0]
    body_start, n_body, level = [0], [n], [0]
    # recursion over (cell, body range, depth); children appended breadth-last
    stack = [(0, 0, n, 0)]
    while stack:
        cid, s, e, lvl = stack.pop()
        body_start[cid], n_body[cid] = s, e - s
        if e - s <= ncrit or lvl >= depth:
            continue
        # split by the 3-bit Morton digit at this level
        shift = 3 * (depth - lvl - 1)
        digits = (keys[s:e] >> np.uint64(shift)) & np.uint64(7)
        counts = np.bincount(digits.astype(np.int64), minlength=8)
        first_child = len(parent)
        nc = 0
        off = s
        for oct_ in range(8):
            c = counts[oct_]
            if c == 0:
                continue
            parent.append(cid)
            child_start.append(0)
            n_child.append(0)
            body_start.append(off)
            n_body.append(c)
            level.append(lvl + 1)
            stack.append((first_child + nc, off, off + c, lvl + 1))
            nc += 1
            off += c
        child_start[cid], n_child[cid] = first_child, nc

    C = len(parent)
    bmin = np.empty((C, 3))
    bmax = np.empty((C, 3))
    for c in range(C):
        s, nb = body_start[c], n_body[c]
        pts = xs[s:s + nb]
        bmin[c] = pts.min(axis=0)
        bmax[c] = pts.max(axis=0)
    centerc = (bmin + bmax) / 2
    radius = 0.5 * np.linalg.norm(bmax - bmin, axis=1)
    return Tree(
        x=xs, q=qs, perm=order,
        parent=np.asarray(parent, dtype=np.int64),
        child_start=np.asarray(child_start, dtype=np.int64),
        n_child=np.asarray(n_child, dtype=np.int64),
        body_start=np.asarray(body_start, dtype=np.int64),
        n_body=np.asarray(n_body, dtype=np.int64),
        center=centerc, radius=radius, bbox_min=bmin, bbox_max=bmax,
        level=np.asarray(level, dtype=np.int64), ncrit=ncrit,
    )


def reference_dual_traversal(tgt_tree, src_tree, theta: float = 0.5,
                             with_m2p: bool = False):
    """Seed `dual_traversal`: explicit per-pair Python stack."""
    m2l, p2p, m2p = [], [], []
    tc, tr = tgt_tree.center, tgt_tree.radius
    sc, sr = src_tree.center, src_tree.radius
    t_leaf, s_leaf = tgt_tree.is_leaf, src_tree.is_leaf
    truncated = getattr(src_tree, "truncated", None)
    if truncated is None:
        truncated = np.zeros(len(sc), dtype=bool)
    stack = [(0, 0)]
    while stack:
        a, b = stack.pop()
        d = np.linalg.norm(tc[a] - sc[b])
        if (tr[a] + sr[b]) < theta * d:
            m2l.append((a, b))
            continue
        if t_leaf[a] and s_leaf[b]:
            if truncated[b]:
                m2p.append((a, b))
            else:
                p2p.append((a, b))
            continue
        # split the larger cell (or the only splittable one)
        split_target = (not t_leaf[a]) and (s_leaf[b] or tr[a] >= sr[b])
        if split_target:
            cs, nc = tgt_tree.child_start[a], tgt_tree.n_child[a]
            for c in range(cs, cs + nc):
                stack.append((c, b))
        else:
            cs, nc = src_tree.child_start[b], src_tree.n_child[b]
            for c in range(cs, cs + nc):
                stack.append((a, c))
    m2l = np.asarray(m2l, dtype=np.int64).reshape(-1, 2)
    p2p = np.asarray(p2p, dtype=np.int64).reshape(-1, 2)
    m2p = np.asarray(m2p, dtype=np.int64).reshape(-1, 2)
    if with_m2p:
        return m2l, p2p, m2p
    assert len(m2p) == 0, "truncated source cells require with_m2p=True"
    return m2l, p2p


def _dist_point_box(p: np.ndarray, box_lo: np.ndarray, box_hi: np.ndarray) -> float:
    d = np.maximum(np.maximum(box_lo - p, p - box_hi), 0.0)
    return float(np.linalg.norm(d))


def reference_extract_let(tree: Tree, M: np.ndarray, box_lo, box_hi,
                          theta: float = 0.5) -> LETData:
    """Seed `extract_let`: dict-based per-cell BFS over a deque."""
    M = np.asarray(M)
    box_lo = np.asarray(box_lo, dtype=np.float64)
    box_hi = np.asarray(box_hi, dtype=np.float64)

    # BFS so that every cell's children are CONTIGUOUS in the output arrays
    # (the traversal contract: children = child_start .. child_start+n_child)
    cells = [dict(src=0, child_start=0, n_child=0, body_start=0,
                  n_body=0, truncated=False)]
    bodies_x, bodies_q = [], []
    n_bodies = 0
    queue = deque([0])          # output indices awaiting expansion
    while queue:
        out = queue.popleft()
        c = cells[out]["src"]
        dist = _dist_point_box(tree.center[c], box_lo, box_hi)
        if 2.0 * tree.radius[c] < theta * dist and c != 0:
            cells[out]["truncated"] = True
            continue
        if tree.n_child[c] == 0:
            # boundary leaf: ship bodies
            s, nb = tree.body_start[c], tree.n_body[c]
            cells[out]["body_start"] = n_bodies
            cells[out]["n_body"] = int(nb)
            n_bodies += int(nb)
            bodies_x.append(tree.x[s:s + nb])
            bodies_q.append(tree.q[s:s + nb])
            continue
        first = len(cells)
        nc = int(tree.n_child[c])
        for k in range(tree.child_start[c], tree.child_start[c] + nc):
            cells.append(dict(src=int(k), child_start=0, n_child=0,
                              body_start=0, n_body=0, truncated=False))
            queue.append(len(cells) - 1)
        cells[out]["child_start"] = first
        cells[out]["n_child"] = nc

    src = np.array([c["src"] for c in cells], dtype=np.int64)
    return LETData(
        center=tree.center[src].copy(),
        radius=tree.radius[src].copy(),
        M=M[src].copy(),
        child_start=np.array([c["child_start"] for c in cells], dtype=np.int64),
        n_child=np.array([c["n_child"] for c in cells], dtype=np.int64),
        body_start=np.array([c["body_start"] for c in cells], dtype=np.int64),
        n_body=np.array([c["n_body"] for c in cells], dtype=np.int64),
        truncated=np.array([c["truncated"] for c in cells], dtype=bool),
        x=(np.concatenate(bodies_x) if bodies_x else np.zeros((0, 3))),
        q=(np.concatenate(bodies_q) if bodies_q else np.zeros((0,))),
    )


def reference_pad_bodies(tree, cells: np.ndarray, width: int | None = None):
    """Seed `fmm._pad_bodies`: per-cell fill loop."""
    width = width or max(int(tree.ncrit), 1)
    out = -np.ones((len(cells), width), dtype=np.int64)
    for i, c in enumerate(cells):
        s, n = tree.body_start[c], tree.n_body[c]
        out[i, :n] = np.arange(s, s + n)
    return out


def reference_padded_leaf_bodies(tree):
    """Seed `Tree.padded_leaf_bodies`: per-leaf fill loop."""
    leaves = tree.leaves
    out = -np.ones((len(leaves), tree.ncrit), dtype=np.int64)
    for i, c in enumerate(leaves):
        s, n = tree.body_start[c], tree.n_body[c]
        out[i, :n] = np.arange(s, s + n)
    return out
