"""Local essential tree (LET): sender-initiated extraction + grafting (§3).

Each partition owns a *completely local* tree (built from the local bounding
box — no global key).  For every remote partition box, the sender traverses
its own tree and ships the minimal subtree:

  - a cell is ACCEPTED (shipped as a truncated multipole leaf, recursion
    stops) iff      2 * R_cell < theta * dist(center, remote_box)
    — conservative enough that the receiver's dual traversal never needs the
    cell's children (see traversal.dual_traversal docstring for the bound);
  - a leaf that fails the criterion ships its bodies (P2P near the boundary);
  - interior cells that fail ship geometry only (structure for the receiver's
    traversal) and recurse.

The receiver *grafts* the received subtree roots — the global tree is never
materialized (the paper's simplification that keeps the serial code reusable).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.multipole import MultipoleOperators
from repro.core.tree import Tree

__all__ = ["LETData", "extract_let", "graft", "let_nbytes",
           "CELL_BYTES", "BODY_BYTES"]

# wire format: center(3f8) + radius(f8) + M(20f8) + 4 structure int32s
CELL_BYTES = (3 + 1 + 20) * 8 + 16
BODY_BYTES = 4 * 8          # x(3f8) + q(f8)


@dataclass
class LETData:
    """A pruned subtree (what one partition sends to one other partition)."""
    center: np.ndarray       # (S, 3)
    radius: np.ndarray       # (S,)
    M: np.ndarray            # (S, nk) multipoles
    child_start: np.ndarray  # (S,)
    n_child: np.ndarray      # (S,)
    body_start: np.ndarray   # (S,)
    n_body: np.ndarray       # (S,)
    truncated: np.ndarray    # (S,) bool — multipole-sufficient leaf
    x: np.ndarray            # (B, 3) shipped bodies
    q: np.ndarray            # (B,)

    @property
    def n_cells(self) -> int:
        return len(self.radius)

    @property
    def nbytes(self) -> int:
        return self.n_cells * CELL_BYTES + len(self.q) * BODY_BYTES


def _dist_point_box(p: np.ndarray, box_lo: np.ndarray, box_hi: np.ndarray) -> float:
    d = np.maximum(np.maximum(box_lo - p, p - box_hi), 0.0)
    return float(np.linalg.norm(d))


def extract_let(tree: Tree, M: np.ndarray, box_lo, box_hi,
                theta: float = 0.5) -> LETData:
    """Sender-side LET extraction for one remote partition box."""
    M = np.asarray(M)
    box_lo = np.asarray(box_lo, dtype=np.float64)
    box_hi = np.asarray(box_hi, dtype=np.float64)

    # BFS so that every cell's children are CONTIGUOUS in the output arrays
    # (the traversal contract: children = child_start .. child_start+n_child)
    from collections import deque
    cells = [dict(src=0, child_start=0, n_child=0, body_start=0,
                  n_body=0, truncated=False)]
    bodies_x, bodies_q = [], []
    n_bodies = 0
    queue = deque([0])          # output indices awaiting expansion
    while queue:
        out = queue.popleft()
        c = cells[out]["src"]
        dist = _dist_point_box(tree.center[c], box_lo, box_hi)
        if 2.0 * tree.radius[c] < theta * dist and c != 0:
            cells[out]["truncated"] = True
            continue
        if tree.n_child[c] == 0:
            # boundary leaf: ship bodies
            s, nb = tree.body_start[c], tree.n_body[c]
            cells[out]["body_start"] = n_bodies
            cells[out]["n_body"] = int(nb)
            n_bodies += int(nb)
            bodies_x.append(tree.x[s:s + nb])
            bodies_q.append(tree.q[s:s + nb])
            continue
        first = len(cells)
        nc = int(tree.n_child[c])
        for k in range(tree.child_start[c], tree.child_start[c] + nc):
            cells.append(dict(src=int(k), child_start=0, n_child=0,
                              body_start=0, n_body=0, truncated=False))
            queue.append(len(cells) - 1)
        cells[out]["child_start"] = first
        cells[out]["n_child"] = nc

    src = np.array([c["src"] for c in cells], dtype=np.int64)
    return LETData(
        center=tree.center[src].copy(),
        radius=tree.radius[src].copy(),
        M=M[src].copy(),
        child_start=np.array([c["child_start"] for c in cells], dtype=np.int64),
        n_child=np.array([c["n_child"] for c in cells], dtype=np.int64),
        body_start=np.array([c["body_start"] for c in cells], dtype=np.int64),
        n_body=np.array([c["n_body"] for c in cells], dtype=np.int64),
        truncated=np.array([c["truncated"] for c in cells], dtype=bool),
        x=(np.concatenate(bodies_x) if bodies_x else np.zeros((0, 3))),
        q=(np.concatenate(bodies_q) if bodies_q else np.zeros((0,))),
    )


def let_nbytes(let: LETData) -> int:
    return let.nbytes


class _GraftedTree:
    """Tree-like view over a received LETData (duck-typed for traversal)."""

    def __init__(self, let: LETData):
        self.center = let.center
        self.radius = let.radius
        self.child_start = let.child_start
        self.n_child = let.n_child
        self.body_start = let.body_start
        self.n_body = let.n_body
        self.truncated = let.truncated
        self.x = let.x
        self.q = let.q
        self.M = let.M
        self.ncrit = int(let.n_body.max()) if len(let.n_body) else 1

    @property
    def n_cells(self):
        return len(self.radius)

    @property
    def is_leaf(self):
        return self.n_child == 0

    @property
    def leaves(self):
        return np.nonzero(self.is_leaf)[0]


def graft(let: LETData) -> _GraftedTree:
    """Graft a received subtree root (no global tree is ever built)."""
    return _GraftedTree(let)
