"""Local essential tree (LET): sender-initiated extraction + grafting (§3).

Each partition owns a *completely local* tree (built from the local bounding
box — no global key).  For every remote partition box, the sender traverses
its own tree and ships the minimal subtree:

  - a cell is ACCEPTED (shipped as a truncated multipole leaf, recursion
    stops) iff      2 * R_cell < theta * dist(center, remote_box)
    — conservative enough that the receiver's dual traversal never needs the
    cell's children (see traversal.dual_traversal docstring for the bound);
  - a leaf that fails the criterion ships its bodies (P2P near the boundary);
  - interior cells that fail ship geometry only (structure for the receiver's
    traversal) and recurse.

The receiver *grafts* the received subtree roots — the global tree is never
materialized (the paper's simplification that keeps the serial code reusable).

Extraction is a *frontier BFS over arrays*: one (box, cell) row per frontier
entry, a vectorized point-to-box distance / acceptance test per generation,
and child allocation via segmented prefix sums — so `extract_lets` serves all
P−1 remote partition boxes of one sender in a single joint pass (Kailasa et
al.'s "precompute communication metadata once" discipline).  The only Python
loops are over BFS generations and, at assembly time, over boxes — never over
cells.  The output is byte-identical to the seed's per-cell deque BFS
(retained as `repro.core.reference.reference_extract_let`, pinned by golden
tests) because a FIFO deque already expands cells in level order.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core.tree import Tree, _segmented_arange

__all__ = ["LETData", "extract_let", "extract_lets", "graft", "refresh_let",
           "let_nbytes", "CELL_BYTES", "BODY_BYTES"]

# wire format: center(3f8) + radius(f8) + M(20f8) + 4 structure int32s
CELL_BYTES = (3 + 1 + 20) * 8 + 16
BODY_BYTES = 4 * 8          # x(3f8) + q(f8)


@dataclass
class LETData:
    """A pruned subtree (what one partition sends to one other partition)."""
    center: np.ndarray       # (S, 3)
    radius: np.ndarray       # (S,)
    M: np.ndarray            # (S, nk) multipoles
    child_start: np.ndarray  # (S,)
    n_child: np.ndarray      # (S,)
    body_start: np.ndarray   # (S,)
    n_body: np.ndarray       # (S,)
    truncated: np.ndarray    # (S,) bool — multipole-sufficient leaf
    x: np.ndarray            # (B, 3) shipped bodies
    q: np.ndarray            # (B,)
    # refresh bookkeeping (NOT part of the wire format; nbytes is unchanged):
    # sender-side indices that let `refresh_let` rebind the numeric payload to
    # updated coordinates/charges, and the minimum truncation-criterion margin
    # used by api.FMMSession.step's MAC-slack revalidation.
    cell_src: np.ndarray | None = None   # (S,) sender-tree cell ids
    body_src: np.ndarray | None = None   # (B,) sender-tree sorted body ids
    trunc_margin: float = float("inf")   # min over truncated cells of
                                         # theta * dist(center, box) - 2 R

    @property
    def n_cells(self) -> int:
        return len(self.radius)

    @property
    def nbytes(self) -> int:
        return self.n_cells * CELL_BYTES + len(self.q) * BODY_BYTES


def _group_exclusive_cumsum(vals: np.ndarray, groups: np.ndarray) -> np.ndarray:
    """Row-order exclusive prefix sum of non-negative `vals` within each group."""
    if len(vals) == 0:
        return vals.astype(np.int64)
    order = np.argsort(groups, kind="stable")
    v = vals[order]
    g = groups[order]
    cs = np.cumsum(v) - v                      # exclusive over the grouped rows
    first = np.ones(len(v), dtype=bool)
    first[1:] = g[1:] != g[:-1]
    # cs is nondecreasing (vals >= 0), so a running max of the group-start
    # values forward-fills each group's base offset
    base = np.maximum.accumulate(np.where(first, cs, 0))
    out = np.empty(len(v), dtype=np.int64)
    out[order] = cs - base
    return out


def extract_lets(tree: Tree, M: np.ndarray, boxes_lo, boxes_hi,
                 theta: float = 0.5) -> list[LETData]:
    """Sender-side LET extraction for G remote partition boxes in ONE joint
    frontier BFS (columns: box id, source cell, per-box output slot)."""
    M = np.asarray(M)
    lo = np.atleast_2d(np.asarray(boxes_lo, dtype=np.float64))
    hi = np.atleast_2d(np.asarray(boxes_hi, dtype=np.float64))
    G = len(lo)
    if G == 0:
        return []
    center, radius = tree.center, tree.radius
    t_cs, t_nc, t_bs, t_nb = (tree.child_start, tree.n_child,
                              tree.body_start, tree.n_body)

    # frontier columns
    f_g = np.arange(G, dtype=np.int64)
    f_c = np.zeros(G, dtype=np.int64)
    f_out = np.zeros(G, dtype=np.int64)
    cell_count = np.ones(G, dtype=np.int64)    # root slot already allocated
    body_count = np.zeros(G, dtype=np.int64)

    rec_ch = []          # per-generation record arrays (row order = BFS order)
    body_g_ch, body_idx_ch = [], []
    trunc_margin = np.full(G, np.inf)
    while len(f_g):
        c = f_c
        dd = np.maximum(np.maximum(lo[f_g] - center[c], center[c] - hi[f_g]), 0.0)
        dist = np.linalg.norm(dd, axis=1)
        trunc = (2.0 * radius[c] < theta * dist) & (c != 0)
        leaf = ~trunc & (t_nc[c] == 0)
        expand = ~trunc & ~leaf

        ti = np.nonzero(trunc)[0]
        if len(ti):
            np.minimum.at(trunc_margin, f_g[ti],
                          theta * dist[ti] - 2.0 * radius[c[ti]])

        bstart = np.zeros(len(f_g), dtype=np.int64)
        nbody = np.zeros(len(f_g), dtype=np.int64)
        cstart = np.zeros(len(f_g), dtype=np.int64)
        nchild = np.zeros(len(f_g), dtype=np.int64)

        li = np.nonzero(leaf)[0]
        if len(li):
            nb = t_nb[c[li]]
            bstart[li] = body_count[f_g[li]] + _group_exclusive_cumsum(nb, f_g[li])
            nbody[li] = nb
            # gather shipped body indices (per-box order follows row order)
            body_idx_ch.append(np.repeat(t_bs[c[li]], nb) + _segmented_arange(nb))
            body_g_ch.append(np.repeat(f_g[li], nb))
            np.add.at(body_count, f_g[li], nb)

        ei = np.nonzero(expand)[0]
        if len(ei):
            nc = t_nc[c[ei]]
            first = cell_count[f_g[ei]] + _group_exclusive_cumsum(nc, f_g[ei])
            cstart[ei] = first
            nchild[ei] = nc
            np.add.at(cell_count, f_g[ei], nc)
            rep = np.repeat(np.arange(len(ei)), nc)
            seg = _segmented_arange(nc)
            child_c = t_cs[c[ei]][rep] + seg
            child_g = f_g[ei][rep]
            child_out = first[rep] + seg
        else:
            child_c = child_g = child_out = np.zeros(0, dtype=np.int64)

        rec_ch.append((f_g, f_out, c, trunc, cstart, nchild, bstart, nbody))
        f_g, f_c, f_out = child_g, child_c, child_out

    g_all = np.concatenate([r[0] for r in rec_ch])
    out_all = np.concatenate([r[1] for r in rec_ch])
    src_all = np.concatenate([r[2] for r in rec_ch])
    trunc_all = np.concatenate([r[3] for r in rec_ch])
    cstart_all = np.concatenate([r[4] for r in rec_ch])
    nchild_all = np.concatenate([r[5] for r in rec_ch])
    bstart_all = np.concatenate([r[6] for r in rec_ch])
    nbody_all = np.concatenate([r[7] for r in rec_ch])
    bg_all = (np.concatenate(body_g_ch) if body_g_ch else np.zeros(0, np.int64))
    bidx_all = (np.concatenate(body_idx_ch) if body_idx_ch else np.zeros(0, np.int64))

    lets = []
    for b in range(G):                      # box-level loop only
        sel = np.nonzero(g_all == b)[0]
        sel = sel[np.argsort(out_all[sel], kind="stable")]
        src = src_all[sel]
        bsel = bidx_all[bg_all == b]
        lets.append(LETData(
            center=center[src].copy(),
            radius=radius[src].copy(),
            M=M[src].copy(),
            child_start=cstart_all[sel],
            n_child=nchild_all[sel],
            body_start=bstart_all[sel],
            n_body=nbody_all[sel],
            truncated=trunc_all[sel],
            x=(tree.x[bsel].copy() if len(bsel) else np.zeros((0, 3))),
            q=(tree.q[bsel].copy() if len(bsel) else np.zeros((0,))),
            cell_src=src, body_src=bsel,
            trunc_margin=float(trunc_margin[b]),
        ))
    return lets


def extract_let(tree: Tree, M: np.ndarray, box_lo, box_hi,
                theta: float = 0.5) -> LETData:
    """Sender-side LET extraction for one remote partition box."""
    return extract_lets(tree, M, np.asarray(box_lo)[None, :],
                        np.asarray(box_hi)[None, :], theta)[0]


def let_nbytes(let: LETData) -> int:
    return let.nbytes


def refresh_let(let: LETData, tree: Tree, M: np.ndarray) -> LETData:
    """Rebind a LET's numeric payload (multipoles, shipped bodies) to the
    sender's updated coordinates/charges while keeping the pruned *structure*
    byte-for-byte — valid as long as the sender's drift stays within the MAC
    slack budget (api.FMMSession.step).  The wire size is unchanged, so the
    bytes matrix and every protocol schedule stay valid too."""
    if let.cell_src is None or let.body_src is None:
        raise ValueError("LET lacks refresh bookkeeping "
                         "(extracted by the reference path?)")
    M = np.asarray(M)
    return replace(
        let, M=M[let.cell_src].copy(),
        x=(tree.x[let.body_src].copy() if len(let.body_src) else let.x),
        q=(tree.q[let.body_src].copy() if len(let.body_src) else let.q))


class _GraftedTree:
    """Tree-like view over a received LETData (duck-typed for traversal).

    `ncrit` is only a hint here: the plan layer buckets P2P source widths by
    actual leaf population, so one huge boundary leaf no longer forces every
    pair to pad to `n_body.max()` (see plan.build_interaction_plan).
    """

    def __init__(self, let: LETData):
        self.center = let.center
        self.radius = let.radius
        self.child_start = let.child_start
        self.n_child = let.n_child
        self.body_start = let.body_start
        self.n_body = let.n_body
        self.truncated = let.truncated
        self.x = let.x
        self.q = let.q
        self.M = let.M
        self.ncrit = int(let.n_body.max()) if len(let.n_body) else 1

    @property
    def n_cells(self):
        return len(self.radius)

    @property
    def is_leaf(self):
        return self.n_child == 0

    @property
    def leaves(self):
        return np.nonzero(self.is_leaf)[0]


def graft(let: LETData) -> _GraftedTree:
    """Graft a received subtree root (no global tree is ever built)."""
    return _GraftedTree(let)
