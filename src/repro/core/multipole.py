"""Cartesian Taylor multipole operators for the Laplace kernel G(r) = 1/|r|.

This is the numerical heart of the FMM reproduced from the paper (exaFMM's
Laplace Cartesian kernel at order P=4).  A multipole expansion about center c
is the coefficient vector

    M_k = sum_i q_i (x_i - c)^k / k!          for multi-indices |k| <= P-1,

a local expansion is  phi(y) = sum_j L_j (y - c)^j / j!.

The M2L translation needs derivative tensors D_k G up to order 2(P-1).  We
build them with *nested jax.jacfwd* — exact AD instead of hand-derived
recurrences — and gather the unique multi-index entries.  All operators are
pure JAX functions, vmap-able and differentiable.
"""
from __future__ import annotations

import math
from functools import cached_property, lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "multi_indices", "num_coeffs", "p2m", "m2m", "m2l", "l2l", "l2p", "m2p",
    "p2p", "MultipoleOperators",
]


def multi_indices(max_order: int) -> np.ndarray:
    """All 3D multi-indices k with |k| <= max_order, ordered by order then lex."""
    out = []
    for n in range(max_order + 1):
        for kx in range(n, -1, -1):
            for ky in range(n - kx, -1, -1):
                out.append((kx, ky, n - kx - ky))
    return np.array(out, dtype=np.int32)


def num_coeffs(p: int) -> int:
    """Number of coefficients for expansion order p (indices |k| <= p-1)."""
    return (p * (p + 1) * (p + 2)) // 6


def _factorial_prod(idx: np.ndarray) -> np.ndarray:
    f = np.array([math.factorial(i) for i in range(idx.max() + 1)], dtype=np.float64)
    return f[idx[:, 0]] * f[idx[:, 1]] * f[idx[:, 2]]


@lru_cache(maxsize=None)
def _tables(p: int):
    """Precomputed integer/float tables for order-p operators (NumPy, host)."""
    K = multi_indices(p - 1)            # (nk, 3) expansion indices
    E = multi_indices(2 * (p - 1))      # (ne, 3) extended (for M2L derivatives)
    nk, ne = len(K), len(E)
    lookup = {tuple(k): i for i, k in enumerate(E)}
    fact_K = _factorial_prod(K)                       # k!
    order_K = K.sum(axis=1)

    # translation tables: T[j, k] uses monomial at (j - k) (M2M) or (k - j) (L2L)
    m2m_idx = np.zeros((nk, nk), dtype=np.int32)
    m2m_valid = np.zeros((nk, nk), dtype=bool)
    l2l_idx = np.zeros((nk, nk), dtype=np.int32)
    l2l_valid = np.zeros((nk, nk), dtype=bool)
    m2l_idx = np.zeros((nk, nk), dtype=np.int32)      # index of (j + k) in E
    for j in range(nk):
        for k in range(nk):
            d = K[j] - K[k]
            if (d >= 0).all():
                m2m_idx[j, k] = lookup[tuple(d)]
                m2m_valid[j, k] = True
            d = K[k] - K[j]
            if (d >= 0).all():
                l2l_idx[j, k] = lookup[tuple(d)]
                l2l_valid[j, k] = True
            m2l_idx[j, k] = lookup[tuple(K[j] + K[k])]

    # inverse factorial of the *monomial* index per table entry
    fact_E = _factorial_prod(E)
    inv_fact_E = 1.0 / fact_E
    sign_K = np.where(order_K % 2 == 0, 1.0, -1.0)    # (-1)^|k|

    # gather map: for each extended index of order n, the flat position inside
    # the order-n full derivative tensor (shape 3^n), via repeated axes (0/1/2)
    per_order_pos = []
    for n in range(2 * (p - 1) + 1):
        rows = E[E.sum(axis=1) == n]
        pos = []
        for kx, ky, kz in rows:
            digits = [0] * kx + [1] * ky + [2] * kz
            flat = 0
            for dgt in digits:
                flat = flat * 3 + dgt
            pos.append(flat)
        per_order_pos.append(np.array(pos, dtype=np.int32))
    return dict(
        K=K, E=E, nk=nk, ne=ne,
        inv_fact_K=(1.0 / fact_K), sign_K=sign_K, order_K=order_K,
        m2m_idx=m2m_idx, m2m_valid=m2m_valid,
        l2l_idx=l2l_idx, l2l_valid=l2l_valid,
        m2l_idx=m2l_idx, inv_fact_E=inv_fact_E,
        per_order_pos=per_order_pos,
    )


def _green(r):
    return 1.0 / jnp.sqrt(jnp.sum(r * r))


@lru_cache(maxsize=None)
def _deriv_fns(max_order: int):
    fns = [_green]
    f = _green
    for _ in range(max_order):
        f = jax.jacfwd(f)
        fns.append(f)
    return tuple(fns)


class MultipoleOperators:
    """Order-p Cartesian Taylor operators; all methods map over leading dims."""

    def __init__(self, p: int = 4):
        self.p = p
        t = _tables(p)
        self.nk = t["nk"]
        self._K = jnp.asarray(t["K"])
        self._E = jnp.asarray(t["E"])
        self._inv_fact_K = jnp.asarray(t["inv_fact_K"])
        self._sign_K = jnp.asarray(t["sign_K"])
        self._m2m_idx = jnp.asarray(t["m2m_idx"])
        self._m2m_valid = jnp.asarray(t["m2m_valid"])
        self._l2l_idx = jnp.asarray(t["l2l_idx"])
        self._l2l_valid = jnp.asarray(t["l2l_valid"])
        self._m2l_idx = jnp.asarray(t["m2l_idx"])
        self._inv_fact_E = jnp.asarray(t["inv_fact_E"])
        self._per_order_pos = [jnp.asarray(x) for x in t["per_order_pos"]]
        self._max_order = 2 * (p - 1)

    # ---- building blocks -------------------------------------------------
    def _monomials_ext(self, d):
        """d^k for every extended multi-index k. d: (3,) -> (ne,)."""
        pows = d[:, None] ** jnp.arange(self._max_order + 1, dtype=d.dtype)  # (3, max+1)
        return pows[0, self._E[:, 0]] * pows[1, self._E[:, 1]] * pows[2, self._E[:, 2]]

    def _monomials_k(self, d):
        K = self._K
        pows = d[:, None] ** jnp.arange(self.p, dtype=d.dtype)
        return pows[0, K[:, 0]] * pows[1, K[:, 1]] * pows[2, K[:, 2]]

    def derivs(self, d):
        """All derivative values D_k G(d) for |k| <= 2(p-1). d: (3,) -> (ne,)."""
        fns = _deriv_fns(self._max_order)
        parts = []
        for n in range(self._max_order + 1):
            full = fns[n](d)                      # tensor of shape (3,)*n
            flat = jnp.reshape(full, (-1,))
            parts.append(flat[self._per_order_pos[n]])
        return jnp.concatenate(parts)

    # ---- kernels ----------------------------------------------------------
    def p2m(self, q, x, center):
        """q: (n,), x: (n,3), center: (3,) -> (nk,). Padded bodies: q=0."""
        mono = jax.vmap(self._monomials_k)(x - center[None, :])   # (n, nk)
        return (q[:, None] * mono).sum(axis=0) * self._inv_fact_K

    def m2m(self, M, d):
        """Translate multipole by d = c_child - c_parent."""
        mono = self._monomials_ext(d)
        T = jnp.where(self._m2m_valid,
                      mono[self._m2m_idx] * self._inv_fact_E[self._m2m_idx], 0.0)
        return T @ M

    def m2l(self, M, d):
        """Multipole at c_M -> local at c_L; d = c_L - c_M."""
        D = self.derivs(d)                                       # (ne,)
        T = D[self._m2l_idx] * self._sign_K[None, :]             # (nk, nk)
        return T @ M

    def l2l(self, L, d):
        """Translate local by d = c_child - c_parent."""
        mono = self._monomials_ext(d)
        T = jnp.where(self._l2l_valid,
                      mono[self._l2l_idx] * self._inv_fact_E[self._l2l_idx], 0.0)
        return T @ L

    def l2p(self, L, y, center):
        """Evaluate local expansion at targets y: (n,3) -> (n,)."""
        mono = jax.vmap(self._monomials_k)(y - center[None, :])  # (n, nk)
        return mono @ (L * self._inv_fact_K)

    def m2p(self, M, y, center):
        """Direct multipole evaluation at targets (treecode-style; for tests)."""
        def one(yi):
            D = self.derivs(yi - center)
            return jnp.sum(M * self._sign_K * D[self._m2l_idx[0, :]])
        # m2l_idx[0, :] maps k -> index of (0 + k) = k in E
        return jax.vmap(one)(y)

    # ---- batched (vmapped) operators --------------------------------------
    # One vmap per operator, built once per operator set: the jitted executors
    # (fmm.py) and the batched multi-tree engine (repro.core.engine) map the
    # same closures over padded leaf/pair tables, so the traced subgraphs —
    # and therefore the JIT cache entries keyed on them — are shared.
    @cached_property
    def p2m_v(self):
        return jax.vmap(self.p2m)

    @cached_property
    def m2m_v(self):
        return jax.vmap(self.m2m)

    @cached_property
    def m2l_v(self):
        return jax.vmap(self.m2l)

    @cached_property
    def l2l_v(self):
        return jax.vmap(self.l2l)

    @cached_property
    def l2p_v(self):
        return jax.vmap(self.l2p)

    @cached_property
    def m2p_v(self):
        return jax.vmap(self.m2p)


# ---- P2P (reference; the Pallas kernel lives in repro.kernels.p2p) --------
def p2p(q_src, x_src, x_tgt, eps2=0.0):
    """Direct Laplace potential: phi_t = sum_s q_s / |x_t - x_s| (self term 0)."""
    d = x_tgt[:, None, :] - x_src[None, :, :]
    r2 = jnp.sum(d * d, axis=-1) + eps2
    inv_r = jnp.where(r2 > 0, jax.lax.rsqrt(jnp.maximum(r2, 1e-30)), 0.0)
    return inv_r @ q_src


@lru_cache(maxsize=None)
def get_operators(p: int = 4) -> "MultipoleOperators":
    """Cached operator set — reuse keeps jit caches warm across trees."""
    return MultipoleOperators(p)


# module-level convenience (order-4, the paper's configuration)
_OPS4 = None


def _ops4():
    global _OPS4
    if _OPS4 is None:
        _OPS4 = MultipoleOperators(4)
    return _OPS4


def p2m(q, x, center):
    return _ops4().p2m(q, x, center)


def m2m(M, d):
    return _ops4().m2m(M, d)


def m2l(M, d):
    return _ops4().m2l(M, d)


def l2l(L, d):
    return _ops4().l2l(L, d)


def l2p(L, y, center):
    return _ops4().l2p(L, y, center)


def m2p(M, y, center):
    return _ops4().m2p(M, y, center)
