"""Single-partition FMM evaluator: host-built plans + JAX arithmetic.

The numeric passes (P2M, M2M, M2L, L2L, L2P, P2P, M2P) run as *jitted,
bucketed* vmaps over the padded index tables of an `FMMPlan`
(repro.core.plan): all list lengths and gather widths are padded to
power-of-two buckets so the JIT cache is shared across trees, partitions and
LET pairs (tree shapes vary; the compiled kernels must not).

Plan construction (traversal, padding, bucketing — pure NumPy geometry) lives
in plan.py; this module only *executes* plans: `execute_fmm_plan` does zero
list construction and zero padding work, so a plan built once can be
evaluated many times (time-stepping, protocol sweeps) at kernel cost only.

Kernel dispatch: `use_kernels=True` routes the P2P hot spot through the
Pallas kernels (repro.kernels); the jnp path is the CPU reference.  The
batched multi-tree execution tier lives in repro.core.engine — these
executors are the per-tree reference it is pinned against.  The legacy
`use_pallas=` flag is a deprecated alias for `use_kernels` (warns once per
call site name, then honors the request).
"""
from __future__ import annotations

import warnings
from functools import partial
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.multipole import MultipoleOperators, get_operators
from repro.core.plan import (FMMPlan, InteractionPlan, TreeSchedules,
                             build_fmm_plan, build_interaction_plan,
                             build_tree_schedules)
from repro.core.tree import Tree, build_tree

__all__ = ["fmm_potential", "evaluate", "execute_fmm_plan", "direct_potential",
           "upward_pass", "downward_pass", "m2l_pass", "m2l_apply", "p2p_pass",
           "p2p_apply", "m2p_pass", "m2p_apply", "l2p_pass", "device_hook"]

_USE_PALLAS_WARNED: set = set()


def _resolve_kernels(use_kernels, use_pallas, where: str) -> bool:
    """Deprecated-flag shim: `use_pallas=` warns once per call site, then is
    honored as `use_kernels` (repo convention: warn-once DeprecationWarning,
    byte-identical behavior)."""
    if use_pallas is None:
        return bool(use_kernels)
    if where not in _USE_PALLAS_WARNED:
        _USE_PALLAS_WARNED.add(where)
        warnings.warn(
            f"{where}(use_pallas=...) is deprecated; use use_kernels=... or "
            "the engine dispatch flag (repro.core.engine.DeviceEngine / "
            "api.FMMSession(engine=...))",
            DeprecationWarning, stacklevel=3)
    return bool(use_pallas)


def device_hook(asarray):
    """Normalize an `asarray=` executor hook (api.DeviceMemo or compatible).

    Contract: the hook must return a *device* array (`jax.Array`) — returning
    a NumPy view would silently re-upload on every kernel call, defeating the
    memoization the hook exists for, so it raises instead.

    Donation-vs-residency contract (the fused tier's mirror image): views
    served by the hook are memoized and shared across callers, so they must
    NEVER be donated to a launch — XLA deletes donated buffers after the
    call, and the memo would keep serving the dead view.  Only per-call
    payload buffers (fresh `jnp.array` copies, or previous donated-launch
    outputs) may be donated; `engine.DeviceEngine._donatable` raises
    `TypeError` on a memo-resident view (`DeviceMemo.is_resident`), exactly
    as this wrapper raises on a host-returning hook."""
    if asarray is None:
        return jnp.asarray

    def checked(arr, dtype=None):
        out = asarray(arr, dtype) if dtype is not None else asarray(arr)
        if not isinstance(out, jax.Array):
            raise TypeError(
                "asarray hook must return a device array (jax.Array), got "
                f"{type(out).__name__}: a NumPy-returning hook would silently "
                "re-upload every table on every call (see api.DeviceMemo)")
        return out

    return checked


def direct_potential(x, q, x_tgt=None, chunk: int = 2048) -> np.ndarray:
    """O(N^2) float64 oracle (self-interaction excluded)."""
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    xt = x if x_tgt is None else np.asarray(x_tgt, dtype=np.float64)
    out = np.zeros(len(xt))
    for s in range(0, len(xt), chunk):
        d = xt[s:s + chunk, None, :] - x[None, :, :]
        r2 = (d ** 2).sum(-1)
        inv = np.where(r2 > 0, 1.0 / np.sqrt(np.maximum(r2, 1e-300)), 0.0)
        out[s:s + chunk] = inv @ q
    return out


# ----------------------------------------------------- jitted kernels ------
@partial(jax.jit, static_argnums=(0,), static_argnames=("n_cells",))
def _p2m_scatter(ops, q, x, centers, leaf_ids, mask, n_cells):
    M_leaf = ops.p2m_v(q, x, centers) * mask[:, None]
    return jnp.zeros((n_cells, ops.nk), jnp.float32).at[leaf_ids].add(M_leaf)


@partial(jax.jit, static_argnums=(0,))
def _m2m_scatter(ops, M, M_child, d, parents, mask):
    contrib = ops.m2m_v(M_child, d) * mask[:, None]
    return M.at[parents].add(contrib)


@partial(jax.jit, static_argnums=(0,), static_argnames=("n_cells",))
def _m2l_scatter(ops, M_src, d, a, mask, n_cells):
    contrib = ops.m2l_v(M_src, d) * mask[:, None]
    return jnp.zeros((n_cells, ops.nk), M_src.dtype).at[a].add(contrib)


@partial(jax.jit, static_argnums=(0,))
def _l2l_scatter(ops, L, L_parent, d, ids, mask):
    contrib = ops.l2l_v(L_parent, d) * mask[:, None]
    return L.at[ids].add(contrib)


@partial(jax.jit, static_argnums=(0,))
def _l2p_vals(ops, L_leaf, y, centers, mask):
    return ops.l2p_v(L_leaf, y, centers) * mask[:, None]


@partial(jax.jit, static_argnums=(0,))
def _m2p_vals(ops, M, y, centers, mask):
    return ops.m2p_v(M, y, centers) * mask[:, None]


@jax.jit
def _p2p_vals(xt, xs, qs, mask):
    d = xt[:, :, None, :] - xs[:, None, :, :]
    r2 = (d * d).sum(-1)
    inv = jnp.where(r2 > 0, jax.lax.rsqrt(jnp.maximum(r2, 1e-30)), 0.0)
    return jnp.einsum("pts,ps->pt", inv, qs) * mask[:, None]


# ------------------------------------------------------------- passes ------
# Every executor takes an optional `asarray` hook (default `jnp.asarray`): a
# session can pass a memoizing uploader (api.DeviceMemo) so the frozen NumPy
# index tables are transferred to the device exactly once, keeping plan.py
# NumPy-only while repeated execution stays kernels-only.  The hook MUST
# return device arrays — `device_hook` enforces the contract.
def upward_pass(tree: Tree, ops: MultipoleOperators,
                sched: TreeSchedules | None = None, asarray=None) -> jnp.ndarray:
    """P2M at leaves, then M2M level-by-level (deepest first). -> (C, nk)."""
    if sched is None:
        sched = build_tree_schedules(tree)
    aa = device_hook(asarray)
    x = aa(tree.x, jnp.float32)
    q = aa(tree.q, jnp.float32)
    xi = x[aa(sched.leaf_idx)]
    qi = jnp.where(aa(sched.leaf_valid), q[aa(sched.leaf_idx)], 0.0)
    M = _p2m_scatter(ops, qi, xi, aa(sched.leaf_centers),
                     aa(sched.leaves), aa(sched.leaf_mask),
                     n_cells=sched.n_cells)
    for ls in reversed(sched.levels):
        M = _m2m_scatter(ops, M, M[aa(ls.ids)], aa(ls.d),
                         aa(ls.parents), aa(ls.mask))
    return M


def downward_pass(tree: Tree, ops, L,
                  sched: TreeSchedules | None = None, asarray=None) -> jnp.ndarray:
    if sched is None:
        sched = build_tree_schedules(tree)
    aa = device_hook(asarray)
    for ls in sched.levels:
        L = _l2l_scatter(ops, L, L[aa(ls.parents)], aa(ls.d),
                         aa(ls.ids), aa(ls.mask))
    return L


def l2p_pass(tree: Tree, ops, L, sched: TreeSchedules | None = None,
             asarray=None) -> np.ndarray:
    if sched is None:
        sched = build_tree_schedules(tree)
    aa = device_hook(asarray)
    y = aa(tree.x, jnp.float32)[aa(sched.leaf_idx)]
    vals = _l2p_vals(ops, L[aa(sched.leaves)], y,
                     aa(sched.leaf_centers), aa(sched.leaf_mask))
    phi = np.zeros(len(tree.x))
    np.add.at(phi, sched.leaf_idx.ravel(),
              np.where(sched.leaf_valid.ravel(),
                       np.asarray(vals, np.float64).ravel(), 0.0))
    return phi


def m2l_apply(ops, M, plan: InteractionPlan, asarray=None) -> jnp.ndarray:
    """Execute the plan's padded M2L list against multipoles M."""
    aa = device_hook(asarray)
    M = aa(M, jnp.float32)
    if plan.n_m2l == 0:
        return jnp.zeros((plan.n_tgt_cells, ops.nk), jnp.float32)
    return _m2l_scatter(ops, M[aa(plan.m2l_b)], aa(plan.m2l_d),
                        aa(plan.m2l_a), aa(plan.m2l_mask),
                        n_cells=plan.n_tgt_cells)


def m2l_pass(ops, M, tgt_tree, src_tree, pairs) -> jnp.ndarray:
    plan = build_interaction_subset(tgt_tree, src_tree, m2l_pairs=pairs)
    return m2l_apply(ops, M, plan)


def build_interaction_subset(tgt_tree, src_tree, m2l_pairs=None,
                             p2p_pairs=None, m2p_pairs=None) -> InteractionPlan:
    """Plan just the supplied pair lists (compat shim for the pair-based API)."""
    empty = np.zeros((0, 2), dtype=np.int64)
    return build_interaction_plan(
        tgt_tree, src_tree,
        m2l_pairs=(empty if m2l_pairs is None else m2l_pairs),
        p2p_pairs=(empty if p2p_pairs is None else p2p_pairs),
        m2p_pairs=m2p_pairs)


def p2p_apply(tgt_tree, src_tree, plan: InteractionPlan,
              use_kernels: bool = False, asarray=None,
              use_pallas: bool | None = None) -> np.ndarray:
    """Execute the plan's bucketed P2P blocks.  Each block's source width is
    sized to its own leaves, so a grafted LET's one big boundary leaf no
    longer inflates every pair's padding."""
    use_kernels = _resolve_kernels(use_kernels, use_pallas, "p2p_apply")
    phi = np.zeros(plan.n_tgt_bodies)
    if plan.n_p2p == 0:
        return phi
    aa = device_hook(asarray)
    xt_all = aa(tgt_tree.x, jnp.float32)
    xs_all = aa(src_tree.x, jnp.float32)
    qs_all = aa(src_tree.q, jnp.float32)
    for blk in plan.p2p_blocks:
        xt = xt_all[aa(blk.t_idx)]
        xs = xs_all[aa(blk.s_idx)]
        qs = jnp.where(aa(blk.s_valid), qs_all[aa(blk.s_idx)], 0.0)
        if use_kernels:
            from repro.kernels.ops import p2p_auto
            vals = np.asarray(p2p_auto(qs, xs, xt)) * blk.mask[:, None]
        else:
            vals = np.asarray(_p2p_vals(xt, xs, qs, aa(blk.mask)))
        np.add.at(phi, blk.t_idx.ravel(),
                  np.where(blk.t_valid.ravel(),
                           vals.astype(np.float64).ravel(), 0.0))
    return phi


def p2p_pass(tgt_tree: Tree, src_tree, pairs, use_kernels: bool = False,
             use_pallas: bool | None = None) -> np.ndarray:
    use_kernels = _resolve_kernels(use_kernels, use_pallas, "p2p_pass")
    plan = build_interaction_subset(tgt_tree, src_tree, p2p_pairs=pairs)
    return p2p_apply(tgt_tree, src_tree, plan, use_kernels=use_kernels)


def m2p_apply(tgt_tree, src_M, plan: InteractionPlan, p: int = 4,
              asarray=None) -> np.ndarray:
    """Execute the plan's padded M2P fallback list (truncated remote cells
    that fail the MAC against a large local leaf)."""
    ops = get_operators(p)
    phi = np.zeros(plan.n_tgt_bodies)
    if plan.n_m2p == 0:
        return phi
    aa = device_hook(asarray)
    y = aa(tgt_tree.x, jnp.float32)[aa(plan.m2p_t_idx)]
    M = aa(src_M, jnp.float32)[aa(plan.m2p_b)]
    vals = np.asarray(_m2p_vals(ops, M, y, aa(plan.m2p_centers),
                                aa(plan.m2p_mask)))
    np.add.at(phi, plan.m2p_t_idx.ravel(),
              np.where(plan.m2p_t_valid.ravel(),
                       vals.astype(np.float64).ravel(), 0.0))
    return phi


def m2p_pass(tgt_tree: Tree, src_M, src_centers, pairs, p: int = 4) -> np.ndarray:
    if len(pairs) == 0:
        return np.zeros(len(tgt_tree.x))
    src = SimpleNamespace(center=src_centers)   # the planner only needs centers
    plan = build_interaction_subset(tgt_tree, src, m2p_pairs=pairs)
    return m2p_apply(tgt_tree, src_M, plan, p=p)


# ------------------------------------------------------- plan execution ----
def execute_fmm_plan(plan: FMMPlan, use_kernels: bool = False,
                     M=None, asarray=None,
                     use_pallas: bool | None = None) -> np.ndarray:
    """Evaluate a prebuilt FMMPlan: kernels + gathers only, no host-side list
    construction or padding.  `M` overrides the source multipoles (grafted
    LETs ship theirs; locally they are rebuilt from the plan's schedules).
    `asarray` optionally memoizes host->device uploads (api.DeviceMemo)."""
    use_kernels = _resolve_kernels(use_kernels, use_pallas, "execute_fmm_plan")
    ops = get_operators(plan.p)
    inter = plan.interactions
    if M is None:
        if plan.src_sched is not None:
            M = upward_pass(plan.src_tree, ops, sched=plan.src_sched,
                            asarray=asarray)
        else:
            M = plan.src_tree.M           # grafted LET: shipped multipoles
    L = m2l_apply(ops, M, inter, asarray=asarray)
    L = downward_pass(plan.tgt_tree, ops, L, sched=plan.tgt_sched,
                      asarray=asarray)
    phi = l2p_pass(plan.tgt_tree, ops, L, sched=plan.tgt_sched, asarray=asarray)
    phi += p2p_apply(plan.tgt_tree, plan.src_tree, inter,
                     use_kernels=use_kernels, asarray=asarray)
    if inter.n_m2p:
        phi += m2p_apply(plan.tgt_tree, M, inter, p=plan.p, asarray=asarray)
    return phi


def evaluate(tgt_tree: Tree, src_tree: Tree, theta: float = 0.5, p: int = 4,
             m2l_pairs=None, p2p_pairs=None, use_kernels: bool = False,
             plan: FMMPlan | None = None,
             use_pallas: bool | None = None) -> np.ndarray:
    """Potential at tgt_tree bodies (sorted order) due to src_tree bodies.
    Pass a prebuilt `plan` (see plan.build_fmm_plan) to skip all host-side
    geometry work."""
    use_kernels = _resolve_kernels(use_kernels, use_pallas, "evaluate")
    if plan is None:
        plan = build_fmm_plan(tgt_tree, src_tree, theta=theta, p=p,
                              m2l_pairs=m2l_pairs, p2p_pairs=p2p_pairs)
    return execute_fmm_plan(plan, use_kernels=use_kernels)


def fmm_potential(x, q, theta: float = 0.5, ncrit: int = 64, p: int = 4,
                  use_kernels: bool = False,
                  use_pallas: bool | None = None) -> np.ndarray:
    """FMM potential in the *original* body order."""
    use_kernels = _resolve_kernels(use_kernels, use_pallas, "fmm_potential")
    tree = build_tree(x, q, ncrit=ncrit)
    phi_sorted = evaluate(tree, tree, theta=theta, p=p,
                          use_kernels=use_kernels)
    out = np.empty_like(phi_sorted)
    out[tree.perm] = phi_sorted
    return out
