"""Single-partition FMM evaluator: host-built tree/lists + JAX arithmetic.

The numeric passes (P2M, M2M, M2L, L2L, L2P, P2P) run as *jitted, bucketed*
vmaps over padded index lists: all list lengths are padded to power-of-two
buckets so the JIT cache is shared across trees, partitions and LET pairs
(tree shapes vary; the compiled kernels must not).  The P2P hot spot can
route through the Pallas kernel (repro.kernels) — the jnp path is the CPU
reference.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.multipole import MultipoleOperators, get_operators
from repro.core.traversal import dual_traversal
from repro.core.tree import Tree, build_tree

__all__ = ["fmm_potential", "evaluate", "direct_potential", "upward_pass",
           "downward_pass", "m2l_pass", "p2p_pass", "m2p_pass", "l2p_pass"]


def direct_potential(x, q, x_tgt=None, chunk: int = 2048) -> np.ndarray:
    """O(N^2) float64 oracle (self-interaction excluded)."""
    x = np.asarray(x, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    xt = x if x_tgt is None else np.asarray(x_tgt, dtype=np.float64)
    out = np.zeros(len(xt))
    for s in range(0, len(xt), chunk):
        d = xt[s:s + chunk, None, :] - x[None, :, :]
        r2 = (d ** 2).sum(-1)
        inv = np.where(r2 > 0, 1.0 / np.sqrt(np.maximum(r2, 1e-300)), 0.0)
        out[s:s + chunk] = inv @ q
    return out


# --------------------------------------------------------- bucketing -------
def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _pad_pairs(pairs: np.ndarray):
    """Pad pair lists to power-of-2 buckets so the vmapped kernels hit the
    JIT cache across trees/partitions."""
    n = len(pairs)
    m = _bucket(max(n, 1))
    # pad by replicating the first pair: keeps indices valid (root cells can
    # be huge) and keeps m2l displacements nonzero; masks zero the values
    out = np.tile(pairs[0], (m, 1)).astype(np.int64) if n else np.zeros((m, 2), np.int64)
    out[:n] = pairs
    mask = np.zeros(m, dtype=np.float32)
    mask[:n] = 1.0
    return out, mask


def _pad_ids(ids: np.ndarray, pad_value: int | None = None):
    n = len(ids)
    m = _bucket(max(n, 1))
    fill = (ids[0] if (pad_value is None and n) else (pad_value or 0))
    out = np.full(m, fill, dtype=np.int64)
    out[:n] = ids
    mask = np.zeros(m, dtype=np.float32)
    mask[:n] = 1.0
    return out, mask


def _pad_bodies(tree, cells: np.ndarray, width: int | None = None):
    """(len(cells), width) body index (into sorted arrays), -1 padded."""
    width = width or max(int(tree.ncrit), 1)
    out = -np.ones((len(cells), width), dtype=np.int64)
    for i, c in enumerate(cells):
        s, n = tree.body_start[c], tree.n_body[c]
        out[i, :n] = np.arange(s, s + n)
    return out


# ----------------------------------------------------- jitted kernels ------
@partial(jax.jit, static_argnums=(0,), static_argnames=("n_cells",))
def _p2m_scatter(ops, q, x, centers, leaf_ids, mask, n_cells):
    M_leaf = jax.vmap(ops.p2m)(q, x, centers) * mask[:, None]
    return jnp.zeros((n_cells, ops.nk), jnp.float32).at[leaf_ids].add(M_leaf)


@partial(jax.jit, static_argnums=(0,))
def _m2m_scatter(ops, M, M_child, d, parents, mask):
    contrib = jax.vmap(ops.m2m)(M_child, d) * mask[:, None]
    return M.at[parents].add(contrib)


@partial(jax.jit, static_argnums=(0,), static_argnames=("n_cells",))
def _m2l_scatter(ops, M_src, d, a, mask, n_cells):
    contrib = jax.vmap(ops.m2l)(M_src, d) * mask[:, None]
    return jnp.zeros((n_cells, ops.nk), M_src.dtype).at[a].add(contrib)


@partial(jax.jit, static_argnums=(0,))
def _l2l_scatter(ops, L, L_parent, d, ids, mask):
    contrib = jax.vmap(ops.l2l)(L_parent, d) * mask[:, None]
    return L.at[ids].add(contrib)


@partial(jax.jit, static_argnums=(0,))
def _l2p_vals(ops, L_leaf, y, centers, mask):
    return jax.vmap(ops.l2p)(L_leaf, y, centers) * mask[:, None]


@partial(jax.jit, static_argnums=(0,))
def _m2p_vals(ops, M, y, centers, mask):
    return jax.vmap(ops.m2p)(M, y, centers) * mask[:, None]


@jax.jit
def _p2p_vals(xt, xs, qs, mask):
    d = xt[:, :, None, :] - xs[:, None, :, :]
    r2 = (d * d).sum(-1)
    inv = jnp.where(r2 > 0, jax.lax.rsqrt(jnp.maximum(r2, 1e-30)), 0.0)
    return jnp.einsum("pts,ps->pt", inv, qs) * mask[:, None]


# ------------------------------------------------------------- passes ------
def upward_pass(tree: Tree, ops: MultipoleOperators) -> jnp.ndarray:
    """P2M at leaves, then M2M level-by-level (deepest first). -> (C, nk)."""
    x = jnp.asarray(tree.x, jnp.float32)
    q = jnp.asarray(tree.q, jnp.float32)
    leaves, lmask = _pad_ids(tree.leaves)
    pad = _pad_bodies(tree, leaves)
    safe = np.where(pad < 0, 0, pad)
    xi = x[jnp.asarray(safe)]
    qi = jnp.where(jnp.asarray(pad >= 0), q[jnp.asarray(safe)], 0.0)
    centers = jnp.asarray(tree.center[leaves], jnp.float32)
    M = _p2m_scatter(ops, qi, xi, centers, jnp.asarray(leaves),
                     jnp.asarray(lmask), n_cells=tree.n_cells)

    for ids in tree.levels_desc():
        ids = ids[ids != 0]
        if len(ids) == 0:
            continue
        ids_p, mask = _pad_ids(ids)
        pa = tree.parent[ids_p]
        d = jnp.asarray((tree.center[ids_p] - tree.center[pa]).astype(np.float32))
        M = _m2m_scatter(ops, M, M[jnp.asarray(ids_p)], d, jnp.asarray(pa),
                         jnp.asarray(mask))
    return M


def m2l_pass(ops, M, tgt_tree, src_tree, pairs) -> jnp.ndarray:
    M = jnp.asarray(M, jnp.float32)
    if len(pairs) == 0:
        return jnp.zeros((tgt_tree.n_cells, ops.nk), jnp.float32)
    pairs, mask = _pad_pairs(pairs)
    a, b = pairs[:, 0], pairs[:, 1]
    d = jnp.asarray((tgt_tree.center[a] - src_tree.center[b]).astype(np.float32))
    return _m2l_scatter(ops, M[jnp.asarray(b)], d, jnp.asarray(a),
                        jnp.asarray(mask), n_cells=tgt_tree.n_cells)


def downward_pass(tree: Tree, ops, L) -> jnp.ndarray:
    max_lvl = int(tree.level.max())
    for lvl in range(1, max_lvl + 1):
        ids = np.nonzero(tree.level == lvl)[0]
        if len(ids) == 0:
            continue
        ids_p, mask = _pad_ids(ids)
        pa = tree.parent[ids_p]
        d = jnp.asarray((tree.center[ids_p] - tree.center[pa]).astype(np.float32))
        L = _l2l_scatter(ops, L, L[jnp.asarray(pa)], d, jnp.asarray(ids_p),
                         jnp.asarray(mask))
    return L


def l2p_pass(tree: Tree, ops, L) -> np.ndarray:
    leaves, lmask = _pad_ids(tree.leaves)
    pad = _pad_bodies(tree, leaves)
    safe = np.where(pad < 0, 0, pad)
    y = jnp.asarray(tree.x, jnp.float32)[jnp.asarray(safe)]
    centers = jnp.asarray(tree.center[leaves], jnp.float32)
    vals = _l2p_vals(ops, L[jnp.asarray(leaves)], y, centers, jnp.asarray(lmask))
    phi = np.zeros(len(tree.x))
    np.add.at(phi, safe.ravel(),
              np.where(pad.ravel() < 0, 0.0, np.asarray(vals, np.float64).ravel()))
    return phi


def p2p_pass(tgt_tree: Tree, src_tree, pairs, use_pallas: bool = False) -> np.ndarray:
    phi = np.zeros(len(tgt_tree.x))
    if len(pairs) == 0:
        return phi
    pairs, mask = _pad_pairs(pairs)
    tp = _pad_bodies(tgt_tree, pairs[:, 0])
    sp = _pad_bodies(src_tree, pairs[:, 1], width=max(int(src_tree.ncrit), 1))
    safe_t = np.where(tp < 0, 0, tp)
    safe_s = np.where(sp < 0, 0, sp)
    xt = jnp.asarray(tgt_tree.x, jnp.float32)[jnp.asarray(safe_t)]
    xs = jnp.asarray(src_tree.x, jnp.float32)[jnp.asarray(safe_s)]
    qs = jnp.where(jnp.asarray(sp >= 0),
                   jnp.asarray(src_tree.q, jnp.float32)[jnp.asarray(safe_s)], 0.0)
    if use_pallas:
        from repro.kernels.ops import p2p_blocked
        vals = np.asarray(p2p_blocked(qs, xs, xt)) * mask[:, None]
    else:
        vals = np.asarray(_p2p_vals(xt, xs, qs, jnp.asarray(mask)))
    np.add.at(phi, safe_t.ravel(),
              np.where(tp.ravel() < 0, 0.0, vals.astype(np.float64).ravel()))
    return phi


def m2p_pass(tgt_tree: Tree, src_M, src_centers, pairs, p: int = 4) -> np.ndarray:
    """Direct multipole evaluation at leaf bodies (LET fallback for truncated
    remote cells that fail the MAC against a large local leaf)."""
    ops = get_operators(p)
    phi = np.zeros(len(tgt_tree.x))
    if len(pairs) == 0:
        return phi
    pairs, mask = _pad_pairs(pairs)
    tp = _pad_bodies(tgt_tree, pairs[:, 0])
    safe = np.where(tp < 0, 0, tp)
    y = jnp.asarray(tgt_tree.x, jnp.float32)[jnp.asarray(safe)]
    M = jnp.asarray(src_M, jnp.float32)[jnp.asarray(pairs[:, 1])]
    centers = jnp.asarray(src_centers, jnp.float32)[jnp.asarray(pairs[:, 1])]
    vals = np.asarray(_m2p_vals(ops, M, y, centers, jnp.asarray(mask)))
    np.add.at(phi, safe.ravel(),
              np.where(tp.ravel() < 0, 0.0, vals.astype(np.float64).ravel()))
    return phi


def evaluate(tgt_tree: Tree, src_tree: Tree, theta: float = 0.5, p: int = 4,
             m2l_pairs=None, p2p_pairs=None, use_pallas: bool = False) -> np.ndarray:
    """Potential at tgt_tree bodies (sorted order) due to src_tree bodies."""
    ops = get_operators(p)
    if m2l_pairs is None or p2p_pairs is None:
        m2l_pairs, p2p_pairs = dual_traversal(tgt_tree, src_tree, theta)
    M = upward_pass(src_tree, ops)
    L = m2l_pass(ops, M, tgt_tree, src_tree, m2l_pairs)
    L = downward_pass(tgt_tree, ops, L)
    phi = l2p_pass(tgt_tree, ops, L)
    phi += p2p_pass(tgt_tree, src_tree, p2p_pairs, use_pallas=use_pallas)
    return phi


def fmm_potential(x, q, theta: float = 0.5, ncrit: int = 64, p: int = 4,
                  use_pallas: bool = False) -> np.ndarray:
    """FMM potential in the *original* body order."""
    tree = build_tree(x, q, ncrit=ncrit)
    phi_sorted = evaluate(tree, tree, theta=theta, p=p, use_pallas=use_pallas)
    out = np.empty_like(phi_sorted)
    out[tree.perm] = phi_sorted
    return out
