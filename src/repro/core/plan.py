"""Interaction plans: the plan/execute split for the FMM host pipeline.

Architecture: plan -> schedule -> dist exchange -> engine -> executable cache
-----------------------------------------------------------------------------
Every FMM evaluation decomposes into two very different kinds of work —
**plan construction** (this module: dual-tree traversal, pair-list padding
and bucketing, leaf body-gather index tables, per-level upward/downward
schedules) and **plan execution** (JAX kernels gathering through the
precomputed index tables with no list construction and no padding work).
Execution comes in two tiers: the per-tree *reference* executors
(`fmm.execute_fmm_plan` and the `*_pass` functions, one launch per tree per
pass) and the *batched device engine* (repro.core.engine), which stacks
every partition's frozen tables into `(n_parts, ...)` envelopes and runs
each phase for the whole geometry in a single launch — one vmapped
multi-tree upward pass, one segment-summed M2L over all (receiver, sender)
pairs, and Pallas-bucketed P2P with autotuned block sizes.

Since the device-resident traversal tier (engine/traversal.py), plan
*construction* itself is backend-split: `traversal_backend="device"` runs
the dual-traversal frontier loop as one `jax.lax.while_loop` program with a
Pallas MAC kernel scoring whole frontiers per launch — emitting the exact
pair lists (same order, same sets) the NumPy reference produces, plus the
minimum accepted-M2L margin the MAC-slack revalidation consumes.  The host
loop in core/traversal.py survives as the f64 *reference*: it is the CPU
default, the precision anchor the f32 device decisions are golden-tested
against, and the fallback wherever no accelerator exists.  Padding,
bucketing and gather-table construction stay NumPy here either way.

The distributed pipeline composes those tiers (repro.core.api), one per
independent axis of the paper plus the hardware floor:

  1. `plan_geometry(x, q, PartitionSpec) -> GeometryPlan` — partitioning,
     completely local trees, batched sender-side LET extraction and every
     receiver's frozen `InteractionPlan`s, built ONCE with no protocol
     argument.  This is the expensive geometry work — traversal on the
     accelerator when one is present — and exactly the "communication
     metadata" Kailasa et al. precompute before any evaluation.
  2. `schedule_comm(geometry, protocol, ...) -> CommSchedule` — a cheap pure
     function over the frozen bytes matrix and Lemma-1 adjacency boxes
     (protocols.py), so sweeping all four exchange protocols reuses one
     `GeometryPlan` with zero re-extraction.
  3. `repro.core.dist` — the exchange tier: when a `shard_map` mesh is
     present (`FMMSession(mesh=...)`; `launch.mesh.host_device_mesh(n)` for
     virtual CPU devices), the stacked envelopes shard over a 1-D rank axis
     and the CommSchedule's modeled transfers execute as real collective
     programs — bulk `all_to_all`, granularity-tuned `ppermute` rounds, or
     the HSDX relay tree — over a frozen wire layout whose span bytes equal
     the `GeometryPlan` bytes matrix exactly (layout.py / programs.py /
     engine.ShardedEngine).  Without a mesh this tier is skipped and the
     schedules remain LogGP-modeled only.
  4. `engine.DeviceEngine(geometry)` — the execution tier: payload-
     independent stacked index tables compiled once per geometry, LET
     indices translated to sender-global device ids (no LET payload ever
     materializes on the host).  Within-slack timesteps upload ONE new_x
     array, revalidate every partition's MAC slack in one batched drift
     launch, adopt the device-restacked payload, and recompute drifting
     multipoles on device.  With x64 enabled the f64 phi accumulation also
     stays on device and returns a single (N,) array; otherwise f64
     accumulation happens once on the host at the API boundary.
  5. `engine.fused` + `engine.exe_cache` — the serving tier: the per-phase
     launches collapse into ONE donated entry computation per warm
     `evaluate()` / within-slack `step()` (`fused=` flag), and the
     AOT-compiled executable (`jax.jit(...).lower(...).compile()`) is
     cached by *shape class* — padded table dims, device dtypes/x64,
     theta-bucket, backend, kernel statics (`schedules
     .shape_class_digest`) — so a new geometry of an already-seen shape
     class pays zero XLA compile time.  Donation-vs-residency contract:
     memoized `DeviceMemo` table views are never donated (a donated buffer
     is deleted, poisoning the memo); per-call payload buffers are always
     donated and threaded through to outputs for input-output aliasing.
  6. `FMMSession` — orchestration: memoized device views, protocol sweeps
     from a single evaluation, `.step(new_x)` MAC-slack revalidation that
     rebuilds only invalidated partitions, engine/reference dispatch
     (`engine=` flag, default on when a device backend is present), the
     fused/per-phase knob (`fused=`, `exe_cache_stats`), and multi-device
     dispatch (`mesh=`, `dist_protocol=`, `exchange_stats`).

Threaded through all six tiers — not a tier of its own — is the
observability layer (`repro.obs`): nested wall-time spans around plan
construction, engine phases and exchanges (with opt-in
`block_until_ready` fences for device timing), a process-wide metrics
registry absorbing the scattered counters (memo hits, cache misses,
autotune decisions, donation events), and mesh-session probes comparing
measured exchange time against the LogGP prediction (`model_drift`).
Disabled — the default — it costs one global load per call site;
`FMMSession.report()` and `Tracer.to_chrome_trace()` are the read side.

Also cross-cutting is the resilience tier (`repro.resilience`): named fault
seams threaded through the stack (`faults.fire(site)` — autotune cache I/O,
XLA compilation, stream-table build, Pallas launches, memo uploads, exchange
-program builds, fused launches) and a degradation ladder the session walks
when a rung fails (`fallback.LADDER`): dist exchange -> streaming Pallas ->
gathered Pallas -> XLA slab -> per-phase engine -> host f64 reference.
Transient failures retry with deterministic backoff; every downgrade is
ledgered (`resilience.fallback` counters, warn-once, the `degraded` flag in
`report()["resilience"]`); ladder exhaustion raises a typed
`ResilienceError` naming the failing site.  Like obs, disabled costs one
global load + None test per seam (`REPRO_FAULTS=` / `REPRO_RESILIENCE=` /
`FMMSession(resilience=...)` are the switches).

Streaming vs gathered P2P.  The engine evaluates the near field one of two
ways.  The *gathered* path (`engine/p2p.p2p_bucket_vals`) materializes each
width-class bucket's `(pairs, S, 3)`/`(pairs, S)` operands via XLA gathers
before its launch — robust to any index pattern, but one HBM round-trip per
bucket.  The *streaming* path (`engine/schedules.build_p2p_stream_tables` +
`kernels/p2p_stream`) concatenates ALL width classes into one unified tile
table `[src_start, src_len, tgt_start, tgt_len]` and runs one grid that
gathers source/target slabs inside the kernel as double-buffered VMEM DMAs.
It is only legal because this module's gather tables make every bucket
row's flat ids a contiguous run (`padded_body_gather` emits
`body_start + arange`, and the engine's LET translation preserves per-leaf
runs); `build_p2p_stream_tables` verifies that invariant at build time and
returns None on violation, falling back to gathered buckets — correctness
never depends on the fast path.  Selection: `FMMSession(p2p_stream=...)`,
default on iff the backend is TPU (`engine.default_p2p_stream`); with
`use_kernels=False` the same unified table runs as one XLA slab program
(`p2p_stream_gathered`), the CPU/CI route.  VMEM budget: scratch is
`n_buffers * 4 * (smax + block_t)` f32s (SoA [x;y;z;q] source + target
slabs), and the `(block_t, n_buffers)` autotune (`kernels.p2p
.best_stream_params`) shrinks block_t until two buffers fit ~1 MB, keeping
double buffering resident alongside the accumulator tile.

A plan is built once and executed many times — time-stepped N-body where
geometry changes slowly, or protocol sweeps over the same partitioning —
which is what makes the host side disappear from the hot path.  All plan
dataclasses are frozen: a plan is immutable geometry metadata.  Device
residency of the frozen tables is the session/engine concern
(api.DeviceMemo threads through the executors' `asarray` hook, and the
engine's stacked tables ride the same memo).

Key structures:

  - `InteractionPlan` — padded M2L pair arrays (with precomputed f32
    displacement vectors), P2P pair *blocks bucketed by source-leaf width*
    (one huge boundary leaf in a grafted LET no longer forces every pair to
    pad to the global maximum — the O(pairs × max_leaf²) blowup the seed's
    single-width padding had), and padded M2P fallback pairs.
  - `TreeSchedules` — padded leaf gathers plus per-level (ids, parents,
    displacement) arrays shared by the upward and downward passes.
  - `FMMPlan` — one (target tree, source tree) evaluation: interactions +
    both trees' schedules.

All pad widths and bucket sizes are powers of two so the jitted kernels hit
the JIT cache across trees, partitions and LET pairs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.traversal import dual_traversal
from repro.core.tree import bucket_size

__all__ = [
    "P2PBlock", "InteractionPlan", "LevelSchedule", "TreeSchedules", "FMMPlan",
    "bucket_size", "pad_pairs", "pad_ids", "padded_body_gather",
    "build_p2p_blocks", "build_interaction_plan", "build_tree_schedules",
    "build_fmm_plan",
]

_EMPTY_PAIRS = np.zeros((0, 2), dtype=np.int64)


# ------------------------------------------------------- padding helpers ---
# bucket_size lives in tree.py (one power-of-two rule for plan padding and
# device cell tables alike) and is re-exported here for its historic callers.
def pad_pairs(pairs: np.ndarray):
    """Pad a (n, 2) pair list to a power-of-2 bucket.  Padding replicates the
    first pair: indices stay valid (root cells can be huge) and M2L
    displacements stay nonzero; the mask zeroes the values."""
    n = len(pairs)
    m = bucket_size(max(n, 1))
    out = np.tile(pairs[0], (m, 1)).astype(np.int64) if n else np.zeros((m, 2), np.int64)
    out[:n] = pairs
    mask = np.zeros(m, dtype=np.float32)
    mask[:n] = 1.0
    return out, mask


def pad_ids(ids: np.ndarray, pad_value: int | None = None):
    n = len(ids)
    m = bucket_size(max(n, 1))
    fill = (ids[0] if (pad_value is None and n) else (pad_value or 0))
    out = np.full(m, fill, dtype=np.int64)
    out[:n] = ids
    mask = np.zeros(m, dtype=np.float32)
    mask[:n] = 1.0
    return out, mask


def padded_body_gather(tree, cells: np.ndarray, width: int):
    """(len(cells), width) body gather table: clipped-safe indices + validity
    mask, built with one broadcast (no per-cell loop)."""
    nb = np.asarray(tree.n_body)[cells]
    if width < 1 or int(nb.max(initial=0)) > width:
        # never truncate silently (matches Tree.padded_leaf_bodies)
        raise ValueError("padded_body_gather: cell population exceeds gather width")
    col = np.arange(width, dtype=np.int64)
    idx = np.asarray(tree.body_start)[cells][:, None] + col[None, :]
    valid = col[None, :] < nb[:, None]
    return np.where(valid, idx, 0), valid


# ------------------------------------------------------------ dataclasses --
@dataclass(frozen=True)
class P2PBlock:
    """One bucket of P2P leaf pairs whose source leaves share a padded width."""
    n: int                   # valid pairs
    mask: np.ndarray         # (B,) float32
    t_idx: np.ndarray        # (B, wt) clipped-safe target body gather
    t_valid: np.ndarray      # (B, wt) bool
    s_idx: np.ndarray        # (B, ws) clipped-safe source body gather
    s_valid: np.ndarray      # (B, ws) bool

    @property
    def shape(self):
        return (len(self.mask), self.t_idx.shape[1], self.s_idx.shape[1])


@dataclass(frozen=True)
class InteractionPlan:
    """Padded, bucketed interaction lists for one (target, source) tree pair."""
    n_tgt_cells: int
    n_tgt_bodies: int
    # M2L: padded pair arrays + precomputed displacement vectors
    n_m2l: int
    m2l_a: np.ndarray        # (B,) padded target cell ids
    m2l_b: np.ndarray        # (B,) padded source cell ids
    m2l_mask: np.ndarray     # (B,) float32
    m2l_d: np.ndarray        # (B, 3) float32  tgt_center - src_center
    # P2P: blocks bucketed by source-leaf width
    n_p2p: int
    p2p_blocks: tuple
    # M2P fallback (truncated LET cells vs large local leaves)
    n_m2p: int
    m2p_b: np.ndarray        # (B,) padded source cell ids
    m2p_mask: np.ndarray     # (B,) float32
    m2p_centers: np.ndarray  # (B, 3) float32 source centers
    m2p_t_idx: np.ndarray    # (B, wt)
    m2p_t_valid: np.ndarray  # (B, wt) bool


@dataclass(frozen=True)
class LevelSchedule:
    """One tree level's padded (ids, parents, displacement) arrays — used by
    M2M (child -> parent) and L2L (parent -> child) alike."""
    ids: np.ndarray          # (B,) padded cell ids
    parents: np.ndarray      # (B,)
    mask: np.ndarray         # (B,) float32
    d: np.ndarray            # (B, 3) float32  center[ids] - center[parents]


@dataclass(frozen=True)
class TreeSchedules:
    """Charge-independent schedules for one tree's vertical passes."""
    n_cells: int
    leaves: np.ndarray       # (B,) padded leaf ids
    leaf_mask: np.ndarray    # (B,) float32
    leaf_centers: np.ndarray # (B, 3) float32
    leaf_idx: np.ndarray     # (B, w) clipped-safe body gather
    leaf_valid: np.ndarray   # (B, w) bool
    levels: tuple            # LevelSchedule per level 1..max (top-down order)


@dataclass(frozen=True)
class FMMPlan:
    """Everything needed to evaluate src -> tgt repeatedly with zero host-side
    list construction: build once with `build_fmm_plan`, execute many times
    with `fmm.execute_fmm_plan`."""
    tgt_tree: object
    src_tree: object
    theta: float
    p: int
    interactions: InteractionPlan
    tgt_sched: TreeSchedules
    src_sched: object        # TreeSchedules, or None for grafted LETs
                             # (their multipoles arrive precomputed)


# --------------------------------------------------------------- builders --
def build_p2p_blocks(tgt_tree, src_tree, pairs: np.ndarray,
                     tgt_width: int | None = None) -> tuple:
    """Bucket P2P pairs by power-of-two source-leaf width.

    This replaces the seed's single global source width
    (`src_tree.ncrit == n_body.max()` for grafted LETs), which padded every
    pair to the largest boundary leaf.  Pairs whose source leaves hold 5 and
    500 bodies now land in separate (8-wide and 512-wide) blocks."""
    if len(pairs) == 0:
        return ()
    wt = tgt_width or bucket_size(max(int(tgt_tree.ncrit), 1), lo=8)
    src_nb = np.asarray(src_tree.n_body)[pairs[:, 1]]
    widths = np.maximum(8, 2 ** np.ceil(np.log2(np.maximum(src_nb, 1))).astype(np.int64))
    blocks = []
    for w in np.unique(widths):
        sub = pairs[widths == w]
        padded, mask = pad_pairs(sub)
        t_idx, t_valid = padded_body_gather(tgt_tree, padded[:, 0], wt)
        s_idx, s_valid = padded_body_gather(src_tree, padded[:, 1], int(w))
        blocks.append(P2PBlock(n=len(sub), mask=mask, t_idx=t_idx,
                               t_valid=t_valid, s_idx=s_idx, s_valid=s_valid))
    return tuple(blocks)


def build_interaction_plan(tgt_tree, src_tree, theta: float = 0.5,
                           with_m2p: bool = False,
                           m2l_pairs=None, p2p_pairs=None,
                           m2p_pairs=None,
                           traversal_backend: str | None = None) -> InteractionPlan:
    """Traverse (unless pair lists are supplied) and freeze the padded /
    bucketed interaction lists for one (target, source) tree pair.

    `traversal_backend` selects where the dual traversal runs: "host" (the
    NumPy frontier reference, the default on CPU), "device" (the
    `jax.lax.while_loop` + Pallas MAC program of repro.core.engine.traversal,
    the default on accelerator backends), or None/"auto"."""
    if m2l_pairs is None or p2p_pairs is None:
        from repro.core.engine.traversal import resolve_traversal_backend
        if resolve_traversal_backend(traversal_backend) == "device":
            from repro.core.engine.traversal import device_dual_traversal
            m2l_pairs, p2p_pairs, m2p_d, _ = device_dual_traversal(
                tgt_tree, src_tree, theta, with_m2p=with_m2p)
            if with_m2p:
                m2p_pairs = m2p_d
        elif with_m2p:
            m2l_pairs, p2p_pairs, m2p_pairs = dual_traversal(
                tgt_tree, src_tree, theta, with_m2p=True)
        else:
            m2l_pairs, p2p_pairs = dual_traversal(tgt_tree, src_tree, theta)
    m2l_pairs = np.asarray(m2l_pairs, dtype=np.int64).reshape(-1, 2)
    p2p_pairs = np.asarray(p2p_pairs, dtype=np.int64).reshape(-1, 2)
    m2p_pairs = (np.asarray(m2p_pairs, dtype=np.int64).reshape(-1, 2)
                 if m2p_pairs is not None else _EMPTY_PAIRS)

    wt = bucket_size(max(int(tgt_tree.ncrit), 1), lo=8)

    m2l_p, m2l_mask = pad_pairs(m2l_pairs)
    m2l_d = (np.asarray(tgt_tree.center)[m2l_p[:, 0]]
             - np.asarray(src_tree.center)[m2l_p[:, 1]]).astype(np.float32)

    p2p_blocks = build_p2p_blocks(tgt_tree, src_tree, p2p_pairs, tgt_width=wt)

    if len(m2p_pairs):
        m2p_p, m2p_mask = pad_pairs(m2p_pairs)
        m2p_t_idx, m2p_t_valid = padded_body_gather(tgt_tree, m2p_p[:, 0], wt)
        m2p_centers = np.asarray(src_tree.center)[m2p_p[:, 1]].astype(np.float32)
    else:
        m2p_p = np.zeros((0, 2), dtype=np.int64)
        m2p_mask = np.zeros(0, dtype=np.float32)
        m2p_t_idx = np.zeros((0, wt), dtype=np.int64)
        m2p_t_valid = np.zeros((0, wt), dtype=bool)
        m2p_centers = np.zeros((0, 3), dtype=np.float32)

    return InteractionPlan(
        n_tgt_cells=int(tgt_tree.n_cells),
        n_tgt_bodies=len(tgt_tree.x),
        n_m2l=len(m2l_pairs), m2l_a=m2l_p[:, 0], m2l_b=m2l_p[:, 1],
        m2l_mask=m2l_mask, m2l_d=m2l_d,
        n_p2p=len(p2p_pairs), p2p_blocks=p2p_blocks,
        n_m2p=len(m2p_pairs), m2p_b=m2p_p[:, 1], m2p_mask=m2p_mask,
        m2p_centers=m2p_centers, m2p_t_idx=m2p_t_idx, m2p_t_valid=m2p_t_valid,
    )


def build_tree_schedules(tree) -> TreeSchedules:
    """Freeze the leaf gathers and per-level M2M/L2L index arrays of a tree."""
    leaves, leaf_mask = pad_ids(tree.leaves)
    w = bucket_size(max(int(tree.ncrit), 1), lo=8)
    leaf_idx, leaf_valid = padded_body_gather(tree, leaves, w)
    leaf_centers = np.asarray(tree.center)[leaves].astype(np.float32)
    levels = []
    for lvl in range(1, int(tree.level.max()) + 1):
        ids = np.nonzero(tree.level == lvl)[0]
        if len(ids) == 0:
            continue
        ids_p, mask = pad_ids(ids)
        parents = np.asarray(tree.parent)[ids_p]
        d = (np.asarray(tree.center)[ids_p]
             - np.asarray(tree.center)[parents]).astype(np.float32)
        levels.append(LevelSchedule(ids=ids_p, parents=parents, mask=mask, d=d))
    return TreeSchedules(
        n_cells=int(tree.n_cells), leaves=leaves, leaf_mask=leaf_mask,
        leaf_centers=leaf_centers, leaf_idx=leaf_idx, leaf_valid=leaf_valid,
        levels=tuple(levels),
    )


def build_fmm_plan(tgt_tree, src_tree, theta: float = 0.5, p: int = 4,
                   with_m2p: bool = False,
                   m2l_pairs=None, p2p_pairs=None, m2p_pairs=None,
                   traversal_backend: str | None = None) -> FMMPlan:
    """Build the full plan for evaluating src_tree -> tgt_tree."""
    interactions = build_interaction_plan(
        tgt_tree, src_tree, theta=theta, with_m2p=with_m2p,
        m2l_pairs=m2l_pairs, p2p_pairs=p2p_pairs, m2p_pairs=m2p_pairs,
        traversal_backend=traversal_backend)
    tgt_sched = build_tree_schedules(tgt_tree)
    if src_tree is tgt_tree:
        src_sched = tgt_sched
    elif hasattr(src_tree, "level"):
        src_sched = build_tree_schedules(src_tree)
    else:                    # grafted LET: multipoles are shipped, not built
        src_sched = None
    return FMMPlan(tgt_tree=tgt_tree, src_tree=src_tree, theta=theta, p=p,
                   interactions=interactions, tgt_sched=tgt_sched,
                   src_sched=src_sched)
