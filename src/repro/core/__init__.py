"""The paper's primary contribution, as composable JAX modules.

    multipole.py       Cartesian Taylor FMM operators (AD-built M2L tensors)
    tree.py            adaptive octree with tight (squeezed) cell boxes
    traversal.py       dual-tree MAC traversal (+ LET M2P fallback)
    fmm.py             bucketed, jitted evaluator; O(N^2) oracle
    plan.py            plan/execute split: frozen InteractionPlan / FMMPlan
    reference.py       retained per-element loop baselines (golden-pinned)
    distributions.py   cube / sphere / ellipsoid / plummer workloads
    partition/         Morton + Skilling-Hilbert SFC, HOT histogram splits,
                       hybrid ORB multisection, quality metrics
    let.py             sender-initiated LET extraction + grafting (§3)
    hsdx.py            Lemma-1 adjacency, balanced BFS comm trees, Eq (1)
    protocols.py       alltoallv / NBX / pairwise / HSDX schedules + LogGP
    collectives.py     device-level patterns: ring AG/RS, hierarchical AR,
                       two-stage a2a, grain-chunked overlap, grid exchange
    api.py             layered facade: GeometryPlan (one geometry) ->
                       CommSchedule (any protocol) -> FMMSession (memoized
                       device views, sweeps, MAC-slack timesteps)
    distributed_fmm.py legacy multi-partition entry points (deprecated
                       shims over api.py, pinned byte-identical)
"""
