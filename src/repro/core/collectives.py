"""Paper-derived communication patterns as JAX collectives.

These are the device-level expressions of the paper's three ideas, shared by
the FMM executor and the LM framework:

  granularity (§4.1)  -> ring collectives chunked inside `lax.scan`, so each
                         ppermute chunk overlaps with the consumer compute
                         (the TPU analogue of subtree-grained LET messages);
  HSDX relay  (§4.2)  -> hierarchical collectives: intra-pod stage first,
                         then a small inter-pod stage over the `pod` axis
                         (relaying through "neighbor" groups);
  pairwise    (§4.3)  -> ring/butterfly ppermute schedules that keep every
                         transfer on direct ICI links.

All functions below are written for use inside `shard_map` (they take axis
names), except the `*_sharded` wrappers used with jit+GSPMD.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ring_all_gather", "ring_reduce_scatter", "hierarchical_all_reduce",
    "two_stage_all_to_all", "all_gather_matmul_overlapped",
    "neighbor_exchange", "hsdx_grid_exchange",
]


def _axis_index(axis_name):
    return jax.lax.axis_index(axis_name)


def _axis_size(axis_name):
    if hasattr(jax.lax, "axis_size"):      # jax >= 0.6
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)      # older jax


def _pvary(x, axis_name):
    """Mark a freshly-created array as varying over the manual axis (JAX's
    VMA check requires scan carries to match the body output's vma set)."""
    try:
        return jax.lax.pvary(x, (axis_name,))
    except Exception:
        return x


def ring_all_gather(x, axis_name: str, *, reverse: bool = False):
    """All-gather via N-1 neighbor ppermutes (contention-free ring; §4.3).

    x: (d, ...) local shard -> (N*d, ...) in rank order.  Expressed as a scan
    so XLA can overlap each hop with the consumer's compute when fused.
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    perm = [((i + 1) % n, i) for i in range(n)] if not reverse else \
           [(i, (i + 1) % n) for i in range(n)]

    def step(carry, _):
        buf = jax.lax.ppermute(carry, axis_name, perm)
        return buf, buf

    _, hops = jax.lax.scan(step, x, None, length=n - 1)       # (n-1, d, ...)
    me = _axis_index(axis_name)
    chunks = jnp.concatenate([x[None], hops], axis=0)          # (n, d, ...)
    # chunk t came from rank (me + t) mod n (for the chosen ring direction)
    src = (me + jnp.arange(n)) % n if not reverse else (me - jnp.arange(n)) % n
    order = jnp.argsort(src)
    chunks = jnp.take(chunks, order, axis=0)
    return jnp.reshape(chunks, (n * x.shape[0],) + x.shape[1:])


def ring_reduce_scatter(x, axis_name: str):
    """Reduce-scatter via N-1 neighbor ppermutes. x: (N*d, ...) -> (d, ...)."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    d = x.shape[0] // n
    me = _axis_index(axis_name)
    parts = jnp.reshape(x, (n, d) + x.shape[1:])
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(carry, t):
        # at step t, rank r holds the partial sum for chunk (r - t - 1) mod n;
        # add the local contribution for that chunk and pass it on
        idx = (me - t - 1) % n
        acc = carry + jnp.take(parts, idx, axis=0)
        acc = jax.lax.ppermute(acc, axis_name, perm)
        return acc, None

    init = _pvary(jnp.zeros((d,) + x.shape[1:], x.dtype), axis_name)
    acc, _ = jax.lax.scan(step, init, jnp.arange(n - 1))
    return acc + jnp.take(parts, me, axis=0)


def hierarchical_all_reduce(x, inner_axis: str, outer_axis: str | None):
    """HSDX-shaped all-reduce: reduce-scatter on the dense intra-pod axis,
    tiny all-reduce across pods, all-gather back intra-pod.  Wire bytes on
    the scarce inter-pod links drop by a factor of |inner_axis|."""
    if outer_axis is None:
        return jax.lax.psum(x, inner_axis)
    flat = jnp.reshape(x, (-1,))
    n = _axis_size(inner_axis)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    part = jax.lax.psum_scatter(jnp.reshape(flat, (n, -1)), inner_axis,
                                scatter_dimension=0, tiled=False)
    part = jax.lax.psum(part, outer_axis)
    full = jax.lax.all_gather(part, inner_axis, axis=0, tiled=False)
    flat = jnp.reshape(full, (-1,))
    if pad:
        flat = flat[:-pad]
    return jnp.reshape(flat, x.shape)


def two_stage_all_to_all(x, inner_axis: str, outer_axis: str,
                         split_axis: int = 0, concat_axis: int = 0):
    """Hierarchical all-to-all (the HSDX relay applied to MoE dispatch):
    stage 1 exchanges within the pod, stage 2 across pods — every transfer
    stays on direct links; the flat a2a across both axes is the baseline.

    x leading dim must equal n_inner * n_outer (destination-major order:
    index = outer * n_inner + inner).
    """
    n_in = _axis_size(inner_axis)
    n_out = _axis_size(outer_axis)
    lead = x.shape[split_axis]
    assert lead % (n_in * n_out) == 0, (lead, n_in, n_out)
    # reshape leading dim -> (n_out, n_in, rest)
    shape = x.shape
    x = jnp.moveaxis(x, split_axis, 0)
    x = jnp.reshape(x, (n_out, n_in) + x.shape[1:])
    # stage 1: intra-pod exchange of the inner index
    x = jax.lax.all_to_all(x, inner_axis, split_axis=1, concat_axis=1)
    # stage 2: inter-pod exchange of the outer index
    x = jax.lax.all_to_all(x, outer_axis, split_axis=0, concat_axis=0)
    x = jnp.reshape(x, (n_out * n_in,) + x.shape[2:])
    x = jnp.moveaxis(x, 0, split_axis) if split_axis != 0 else x
    if concat_axis != split_axis:
        x = jnp.moveaxis(x, split_axis, concat_axis)
    return x


def all_gather_matmul_overlapped(x, w, axis_name: str):
    """y = all_gather(x, axis) @ w, decomposed into ring hops so chunk t's
    matmul overlaps hop t+1's ppermute (granularity knob at its optimum
    instead of the bulk-synchronous extreme).

    x: (m, k) local shard of the gathered dim; w: (k, n) replicated (or
    column-sharded outside).  Returns (N*m, n) rows in rank order.
    """
    n_dev = _axis_size(axis_name)
    me = _axis_index(axis_name)
    perm = [((i + 1) % n_dev, i) for i in range(n_dev)]
    m = x.shape[0]
    out = _pvary(jnp.zeros((n_dev * m, w.shape[1]), dtype=jnp.result_type(x, w)),
                 axis_name)

    def step(carry, t):
        buf, out = carry
        nxt = jax.lax.ppermute(buf, axis_name, perm)     # prefetch next chunk
        y = buf @ w                                       # overlap: compute current
        src = (me + t) % n_dev
        out = jax.lax.dynamic_update_slice(out, y, (src * m, 0))
        return (nxt, out), None

    (buf, out), _ = jax.lax.scan(step, (x, out), jnp.arange(n_dev - 1))
    y = buf @ w
    src = (me + n_dev - 1) % n_dev
    out = jax.lax.dynamic_update_slice(out, y, (src * m, 0))
    return out


def neighbor_exchange(x, axis_name: str, shift: int = 1):
    """One HSDX hop: send to the +shift ring neighbor (direct link only)."""
    n = _axis_size(axis_name)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name, perm)


def hsdx_grid_exchange(buf, axis_name: str, grid_shape, stages: int = 1):
    """HSDX on a process grid laid out along a flat axis: at each stage every
    rank exchanges with its 3^D-1 grid neighbors (Algorithm 1's per-level
    Neighbor_alltoallv), implemented as one ppermute per neighbor offset
    (each offset is a full permutation -> contention-free).

    buf: (slots, ...) where slots >= number of neighbor offsets; slot k
    accumulates what arrived from offset k.  Returns (stages, n_offsets, ...)
    received payloads.
    """
    import numpy as np
    gx, gy, gz = grid_shape
    n = gx * gy * gz
    coords = np.array([(i // (gy * gz), (i // gz) % gy, i % gz) for i in range(n)])
    offsets = [(dx, dy, dz) for dx in (-1, 0, 1) for dy in (-1, 0, 1)
               for dz in (-1, 0, 1) if (dx, dy, dz) != (0, 0, 0)]
    recv_stages = []
    x = buf
    for _ in range(stages):
        recvs = []
        for (dx, dy, dz) in offsets:
            tgt = coords + np.array([dx, dy, dz])
            tgt = tgt % np.array(grid_shape)                 # torus wrap (ICI)
            tgt_flat = tgt[:, 0] * gy * gz + tgt[:, 1] * gz + tgt[:, 2]
            perm = [(i, int(tgt_flat[i])) for i in range(n)]
            recvs.append(jax.lax.ppermute(x, axis_name, perm))
        stage_recv = jnp.stack(recvs, axis=0)                # (26, ...)
        x = jnp.mean(stage_recv, axis=0)                     # relay aggregate
        recv_stages.append(stage_recv)
    return jnp.stack(recv_stages, axis=0)
