"""Hybrid ORB: orthogonal recursive *multisection* along the longest dimension
with histogram-refined bisectors, producing tight partition boxes.

This is the paper's partitioner of choice (§2.2): combined with completely
local trees + tight cell bounding boxes it fixes ORB's partition/cell
misalignment defect.  Multisection (not just bisection) supports non-power-of-
two process counts [Makino 2004].
"""
from __future__ import annotations

import numpy as np

__all__ = ["orb_partition", "find_splitter"]


def find_splitter(vals: np.ndarray, frac: float, n_bins: int = 64,
                  max_iter: int = 30, n_proc_chunks: int = 8) -> float:
    """Histogram-refined coordinate splitter: smallest v with
    count(vals < v) >= frac * n.  Communicates only histogram counts."""
    n = len(vals)
    target = int(round(frac * n))
    lo, hi = float(vals.min()), float(vals.max())
    below = 0
    shards = np.array_split(vals, n_proc_chunks)
    for _ in range(max_iter):
        if hi - lo < 1e-12 * max(1.0, abs(hi)):
            break
        edges = np.linspace(lo, hi, n_bins + 1)
        counts = np.zeros(n_bins, dtype=np.int64)
        for sh in shards:
            c, _ = np.histogram(sh, bins=edges)
            counts += c                                    # "MPI_Allreduce"
        cum = below + np.cumsum(counts)
        idx = int(np.argmax(cum >= target)) if (cum >= target).any() else n_bins - 1
        below = below if idx == 0 else int(cum[idx - 1])
        lo, hi = edges[idx], edges[idx + 1]
    return hi


def orb_partition(x: np.ndarray, nparts: int, regions: bool = False):
    """Returns (part_id (N,), tight_boxes (nparts, 2, 3)).

    With regions=True also returns the ORB *region* boxes — the recursive
    split rectangles that partition space exactly.  Tight boxes drive the
    MAC/LET (paper Fig 1d); region boxes share faces by construction and
    define the Lemma-1 adjacency for HSDX.
    """
    x = np.asarray(x, dtype=np.float64)
    n = len(x)
    part = np.zeros(n, dtype=np.int32)
    boxes = np.zeros((nparts, 2, 3))
    rboxes = np.zeros((nparts, 2, 3))

    def recurse(idx: np.ndarray, p0: int, np_: int, rlo, rhi):
        if len(idx) == 0:           # more parts than points: this whole
            for p in range(p0, p0 + np_):   # subtree gets empty-box sentinels
                boxes[p, 0], boxes[p, 1] = np.inf, -np.inf
                rboxes[p, 0], rboxes[p, 1] = np.inf, -np.inf
            return
        if np_ == 1:
            pts = x[idx]
            part[idx] = p0
            boxes[p0, 0] = pts.min(axis=0)
            boxes[p0, 1] = pts.max(axis=0)
            rboxes[p0, 0], rboxes[p0, 1] = rlo, rhi
            return
        pts = x[idx]
        lo, hi = pts.min(axis=0), pts.max(axis=0)
        dim = int(np.argmax(hi - lo))                       # longest dimension
        n_left = np_ // 2
        frac = n_left / np_
        s = find_splitter(pts[:, dim], frac)
        left = pts[:, dim] < s
        # guard degenerate splits (duplicated coordinates)
        if left.sum() == 0 or left.sum() == len(idx):
            order = np.argsort(pts[:, dim], kind="stable")
            k = int(round(frac * len(idx)))
            left = np.zeros(len(idx), dtype=bool)
            left[order[:k]] = True
            s = float(pts[order[k - 1], dim]) if k else float(lo[dim])
        rhi_l = rhi.copy()
        rhi_l[dim] = s
        rlo_r = rlo.copy()
        rlo_r[dim] = s
        recurse(idx[left], p0, n_left, rlo.copy(), rhi_l)
        recurse(idx[~left], p0 + n_left, np_ - n_left, rlo_r, rhi.copy())

    dom_lo, dom_hi = x.min(axis=0), x.max(axis=0)
    recurse(np.arange(n), 0, nparts, dom_lo.copy(), dom_hi.copy())
    if regions:
        return part, boxes, rboxes
    return part, boxes
