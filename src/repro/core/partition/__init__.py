# Subpackages are imported directly (repro.core.partition.sfc etc.) — keep
# this __init__ empty to avoid import cycles with tree.py.
