"""HOT partitioning: split the SFC-key-ordered particle sequence into equal
intervals, with splitter keys found by the paper's histogram refinement
(Fig 2): only global histogram *counts* are communicated (an allreduce of a
few integers), never particle data.  The structure below mirrors that — local
counts per "process" chunk are summed, and bins are refined iteratively.
"""
from __future__ import annotations

import numpy as np

from repro.core.partition.sfc import keys_for_points

__all__ = ["histogram_splitters", "hot_partition"]


def histogram_splitters(keys: np.ndarray, nparts: int, key_hi: int,
                        n_bins: int = 64, max_iter: int = 24,
                        n_proc_chunks: int = 8):
    """Find nparts-1 splitter keys s.t. intervals carry ~equal counts.

    Emulates the distributed algorithm: `keys` is viewed as `n_proc_chunks`
    process-local shards; each refinement step computes local histograms and
    "allreduces" them (np.sum over shards).
    """
    n = len(keys)
    shards = np.array_split(keys, n_proc_chunks)
    targets = (np.arange(1, nparts) * n) // nparts         # global ranks wanted
    lo = np.zeros(nparts - 1, dtype=np.float64)
    hi = np.full(nparts - 1, float(key_hi), dtype=np.float64)
    below_lo = np.zeros(nparts - 1, dtype=np.int64)        # counts < lo
    for _ in range(max_iter):
        if np.all(hi - lo <= 1):
            break
        # bins per splitter: [lo, hi) split n_bins ways
        edges = lo[:, None] + (hi - lo)[:, None] * np.arange(n_bins + 1) / n_bins
        counts = np.zeros((nparts - 1, n_bins), dtype=np.int64)
        for sh in shards:                                   # local histograms
            f = sh.astype(np.float64)
            for s in range(nparts - 1):
                c, _ = np.histogram(f, bins=edges[s])
                counts[s] += c                              # "MPI_Allreduce"
        cum = below_lo[:, None] + np.cumsum(counts, axis=1)
        # bin whose cumulative count first reaches the target rank
        idx = np.argmax(cum >= targets[:, None], axis=1)
        reached = cum[np.arange(nparts - 1), idx] >= targets
        idx = np.where(reached, idx, n_bins - 1)
        new_lo = edges[np.arange(nparts - 1), idx]
        new_hi = edges[np.arange(nparts - 1), idx + 1]
        prev_cum = np.where(idx > 0, cum[np.arange(nparts - 1), idx - 1], below_lo)
        below_lo = prev_cum
        lo, hi = new_lo, new_hi
    return np.ceil(hi).astype(np.uint64)


def hot_partition(x: np.ndarray, nparts: int, curve: str = "hilbert",
                  depth: int = 10):
    """Returns (part_id (N,), splitters)."""
    keys = keys_for_points(x, depth=depth, curve=curve)
    splitters = histogram_splitters(keys, nparts, key_hi=1 << (3 * depth))
    part = np.searchsorted(splitters, keys, side="right").astype(np.int32)
    return part, splitters
