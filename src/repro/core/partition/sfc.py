"""Space-filling-curve keys: Morton (Z-order) and Hilbert (Skilling transform).

Vectorized NumPy over (N, 3) integer grid coordinates.  Hilbert follows John
Skilling, "Programming the Hilbert curve" (AIP CP 707, 2004) — the same curve
family the paper evaluates (and finds wanting for boundary distributions).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "morton_encode", "morton_decode", "hilbert_encode", "hilbert_decode",
    "coords_from_points", "keys_for_points",
]


def _as_grid(ijk) -> np.ndarray:
    g = np.asarray(ijk, dtype=np.uint64)
    if g.ndim == 1:
        g = g[None, :]
    return g


def morton_encode(ijk, depth: int) -> np.ndarray:
    """Interleave bits: key = x2 y2 z2 x1 y1 z1 x0 y0 z0 (x most significant)."""
    g = _as_grid(ijk)
    g = np.clip(g, 0, (1 << depth) - 1)
    key = np.zeros(len(g), dtype=np.uint64)
    for b in range(depth):
        for dim in range(3):
            bit = (g[:, dim] >> np.uint64(b)) & np.uint64(1)
            key |= bit << np.uint64(3 * b + (2 - dim))
    return key


def morton_decode(keys, depth: int) -> np.ndarray:
    k = np.asarray(keys, dtype=np.uint64)
    out = np.zeros((len(k), 3), dtype=np.uint64)
    for b in range(depth):
        for dim in range(3):
            bit = (k >> np.uint64(3 * b + (2 - dim))) & np.uint64(1)
            out[:, dim] |= bit << np.uint64(b)
    return out


def _axes_to_transpose(X: np.ndarray, b: int) -> np.ndarray:
    """Skilling AxestoTranspose, vectorized. X: (N,3) uint64 (modified copy)."""
    X = X.astype(np.uint64).copy()
    M = np.uint64(1 << (b - 1))
    Q = M
    while Q > np.uint64(1):
        P = Q - np.uint64(1)
        for i in range(3):
            hi = (X[:, i] & Q) != 0
            # invert where hi, exchange low bits of X0<->Xi elsewhere
            X[:, 0] = np.where(hi, X[:, 0] ^ P, X[:, 0])
            t = np.where(hi, np.uint64(0), (X[:, 0] ^ X[:, i]) & P)
            X[:, 0] ^= t
            X[:, i] ^= t
        Q >>= np.uint64(1)
    # Gray encode
    for i in range(1, 3):
        X[:, i] ^= X[:, i - 1]
    t = np.zeros(len(X), dtype=np.uint64)
    Q = M
    while Q > np.uint64(1):
        t = np.where((X[:, 2] & Q) != 0, t ^ (Q - np.uint64(1)), t)
        Q >>= np.uint64(1)
    for i in range(3):
        X[:, i] ^= t
    return X


def _transpose_to_axes(X: np.ndarray, b: int) -> np.ndarray:
    X = X.astype(np.uint64).copy()
    N = np.uint64(2 << (b - 1))
    # Gray decode
    t = X[:, 2] >> np.uint64(1)
    for i in (2, 1):
        X[:, i] ^= X[:, i - 1]
    X[:, 0] ^= t
    Q = np.uint64(2)
    while Q != N:
        P = Q - np.uint64(1)
        for i in (2, 1, 0):
            hi = (X[:, i] & Q) != 0
            X[:, 0] = np.where(hi, X[:, 0] ^ P, X[:, 0])
            t = np.where(hi, np.uint64(0), (X[:, 0] ^ X[:, i]) & P)
            X[:, 0] ^= t
            X[:, i] ^= t
        Q <<= np.uint64(1)
    return X


def _pack_transpose(X: np.ndarray, b: int) -> np.ndarray:
    """Interleave transpose-format words into a single Hilbert index."""
    key = np.zeros(len(X), dtype=np.uint64)
    for bit in range(b - 1, -1, -1):
        for dim in range(3):
            v = (X[:, dim] >> np.uint64(bit)) & np.uint64(1)
            key = (key << np.uint64(1)) | v
    return key


def _unpack_transpose(keys: np.ndarray, b: int) -> np.ndarray:
    k = np.asarray(keys, dtype=np.uint64)
    X = np.zeros((len(k), 3), dtype=np.uint64)
    pos = 3 * b - 1
    for bit in range(b - 1, -1, -1):
        for dim in range(3):
            v = (k >> np.uint64(pos)) & np.uint64(1)
            X[:, dim] |= v << np.uint64(bit)
            pos -= 1
    return X


def hilbert_encode(ijk, depth: int) -> np.ndarray:
    g = _as_grid(ijk)
    g = np.clip(g, 0, (1 << depth) - 1)
    return _pack_transpose(_axes_to_transpose(g, depth), depth)


def hilbert_decode(keys, depth: int) -> np.ndarray:
    return _transpose_to_axes(_unpack_transpose(keys, depth), depth)


def coords_from_points(x: np.ndarray, depth: int, bbox=None) -> np.ndarray:
    """Map float points to integer grid coordinates at the given depth."""
    x = np.asarray(x, dtype=np.float64)
    if bbox is None:
        lo, hi = x.min(axis=0), x.max(axis=0)
    else:
        lo, hi = np.asarray(bbox[0]), np.asarray(bbox[1])
    span = np.maximum((hi - lo).max(), 1e-300)
    g = ((x - lo) / (span * (1 + 1e-9)) * (1 << depth)).astype(np.uint64)
    return np.clip(g, 0, (1 << depth) - 1)


def keys_for_points(x: np.ndarray, depth: int = 10, curve: str = "hilbert",
                    bbox=None) -> np.ndarray:
    g = coords_from_points(x, depth, bbox)
    if curve == "hilbert":
        return hilbert_encode(g, depth)
    if curve == "morton":
        return morton_encode(g, depth)
    raise ValueError(f"unknown curve {curve!r}")
