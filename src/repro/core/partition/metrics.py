"""Partition-quality metrics reproducing the paper's §2.2 demonstration:
Hilbert interval partitions of *boundary* (surface) distributions are
spatially discontinuous (Fig 3), which inflates the distributed interaction
lists; hybrid ORB partitions are compact.
"""
from __future__ import annotations

import numpy as np

__all__ = ["load_balance", "connected_components", "partition_report"]


def load_balance(part: np.ndarray, nparts: int) -> float:
    counts = np.bincount(part, minlength=nparts)
    return counts.max() / max(counts.mean(), 1e-12)


def connected_components(x: np.ndarray, grid_depth: int = 3) -> int:
    """Number of connected components of the point set, measured on an
    occupancy grid with 26-neighbor connectivity.  A spatially continuous
    partition has exactly 1; Hilbert-on-sphere partitions show > 1 (Fig 3)."""
    lo, hi = x.min(axis=0), x.max(axis=0)
    span = max((hi - lo).max(), 1e-12)
    g = np.minimum(((x - lo) / (span * (1 + 1e-9)) * (1 << grid_depth)).astype(np.int64),
                   (1 << grid_depth) - 1)
    occ = set(map(tuple, g))
    seen = set()
    comps = 0
    for cell in occ:
        if cell in seen:
            continue
        comps += 1
        stack = [cell]
        seen.add(cell)
        while stack:
            cx, cy, cz = stack.pop()
            for dx in (-1, 0, 1):
                for dy in (-1, 0, 1):
                    for dz in (-1, 0, 1):
                        nb = (cx + dx, cy + dy, cz + dz)
                        if nb in occ and nb not in seen:
                            seen.add(nb)
                            stack.append(nb)
    return comps


def partition_report(x: np.ndarray, part: np.ndarray, nparts: int,
                     grid_depth: int = 3) -> dict:
    """Aggregate quality metrics for a partitioning."""
    comps = [connected_components(x[part == p], grid_depth)
             for p in range(nparts) if (part == p).any()]
    # bbox overlap volume proxy: compact partitions have disjoint tight boxes
    boxes = []
    for p in range(nparts):
        pts = x[part == p]
        if len(pts):
            boxes.append((pts.min(axis=0), pts.max(axis=0)))
    overlap = 0.0
    for i in range(len(boxes)):
        for j in range(i + 1, len(boxes)):
            lo = np.maximum(boxes[i][0], boxes[j][0])
            hi = np.minimum(boxes[i][1], boxes[j][1])
            if np.all(hi > lo):
                overlap += float(np.prod(hi - lo))
    return {
        "balance": load_balance(part, nparts),
        "mean_components": float(np.mean(comps)),
        "max_components": int(np.max(comps)),
        "bbox_overlap_volume": overlap,
    }
