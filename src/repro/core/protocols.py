"""Communication protocol schedules for the LET exchange (§4).

Four protocols over the same payload matrix B[i, j] = bytes partition i must
deliver to partition j:

  alltoallv : 1 bulk-synchronous stage, every nonzero pair sends directly
              (the conventional baseline the paper beats);
  nbx       : direct sparse sends (Hoefler et al.), 1 data stage + a modeled
              log2(P) nonblocking-barrier consensus;
  pairwise  : hypercube / butterfly (P xor 2^i), log2(P) stages, payloads
              routed by bit-correction with relaying (§4.3);
  hsdx      : neighbor-only relay over the Lemma-1 adjacency graph, one
              Neighbor_alltoallv per stage (§4.2, Algorithm 1).

Every schedule is *executed* by a store-and-forward simulator so tests can
assert identical delivery, and costed with a LogGP model including the
eager->rendezvous protocol cliff the paper tunes around (Fig 6).

This module is the pure *transport* layer of the three-layer API
(repro.core.api): `make_schedule` / `loggp_time` are cheap pure functions
over a frozen bytes matrix B and the Lemma-1 adjacency boxes, so
`api.schedule_comm` can sweep all four protocols against one `GeometryPlan`
with zero geometry work.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core import hsdx as hsdx_mod

__all__ = ["LogGPParams", "Schedule", "make_schedule", "simulate_delivery",
           "schedule_stats", "schedule_edge_bytes", "loggp_time", "PROTOCOLS"]

PROTOCOLS = ("alltoallv", "nbx", "pairwise", "hsdx")


@dataclass
class LogGPParams:
    """LogGP + MPI eager/rendezvous cliff (Cray MPICH defaults, Fig 6)."""
    L: float = 2.0e-6           # latency per stage (s)
    o: float = 1.0e-6           # per-message overhead (s)
    G: float = 1.0 / 10e9       # per-byte gap (s/B) ~ 10 GB/s links
    eager_limit: int = 8192     # bytes; above this, rendezvous
    rendezvous_penalty: float = 4.0e-6  # extra handshake per large message


@dataclass
class Transfer:
    src: int
    dst: int
    nbytes: int
    payloads: list = field(default_factory=list)  # [(origin, final_dst, nbytes)]


@dataclass
class Schedule:
    name: str
    nparts: int
    stages: list  # list[list[Transfer]]

    @property
    def n_stages(self) -> int:
        return len(self.stages)


def _payloads(B: np.ndarray):
    out = []
    P = len(B)
    for i in range(P):
        for j in range(P):
            if i != j and B[i, j] > 0:
                out.append((i, j, int(B[i, j])))
    return out


def _alltoallv(B: np.ndarray) -> Schedule:
    stage = [Transfer(i, j, b, [(i, j, b)]) for (i, j, b) in _payloads(B)]
    return Schedule("alltoallv", len(B), [stage])


def _nbx(B: np.ndarray) -> Schedule:
    # data movement identical to alltoallv (direct sparse sends); the
    # difference is the consensus cost, handled in loggp_time.
    s = _alltoallv(B)
    return Schedule("nbx", len(B), s.stages)


def _pairwise(B: np.ndarray) -> Schedule:
    """Hypercube bit-correction routing: at stage i, forward every held
    payload whose destination differs from the holder in bit i."""
    P = len(B)
    nbits = max(1, math.ceil(math.log2(P)))
    held = {r: [] for r in range(P)}
    for (i, j, b) in _payloads(B):
        held[i].append((i, j, b))
    stages = []
    for bit in range(nbits):
        agg: dict[tuple[int, int], Transfer] = {}
        new_held = {r: [] for r in range(P)}
        for r in range(P):
            partner = r ^ (1 << bit)
            for pl in held[r]:
                origin, dst, b = pl
                if dst != r and ((dst ^ r) >> bit) & 1 and partner < P:
                    t = agg.setdefault((r, partner), Transfer(r, partner, 0))
                    t.nbytes += b
                    t.payloads.append(pl)
                    new_held[partner].append(pl)
                else:
                    new_held[r].append(pl)
        held = new_held
        if agg:
            stages.append(list(agg.values()))
    # non-power-of-two P: bit-correction can strand payloads whose partner
    # rank does not exist; deliver the remainder with one direct stage
    # (the classical fold step for non-pow2 hypercubes)
    agg = {}
    for r in range(P):
        for pl in held[r]:
            origin, dst, b = pl
            if dst != r:
                t = agg.setdefault((r, dst), Transfer(r, dst, 0))
                t.nbytes += b
                t.payloads.append(pl)
    if agg:
        stages.append(list(agg.values()))
    return Schedule("pairwise", P, stages)


def _hsdx(B: np.ndarray, boxes: np.ndarray) -> Schedule:
    """Neighbor-relay over Lemma-1 adjacency; one aggregated neighbor
    exchange per stage (Algorithm 1)."""
    P = len(B)
    adj = hsdx_mod.adjacency_from_boxes(boxes)
    routes = hsdx_mod.relay_routes(adj)
    # position of each payload along its route
    inflight = [(i, j, b, routes[(i, j)]) for (i, j, b) in _payloads(B)]
    stages = []
    hop = 0
    while True:
        agg: dict[tuple[int, int], Transfer] = {}
        active = False
        for (i, j, b, path) in inflight:
            if hop + 1 < len(path):
                active = True
                u, v = path[hop], path[hop + 1]
                t = agg.setdefault((u, v), Transfer(u, v, 0))
                t.nbytes += b
                t.payloads.append((i, j, b))
        if not active:
            break
        stages.append(list(agg.values()))
        hop += 1
    return Schedule("hsdx", P, stages)


def make_schedule(name: str, B: np.ndarray, boxes: np.ndarray | None = None) -> Schedule:
    if name == "alltoallv":
        sched = _alltoallv(B)
    elif name == "nbx":
        sched = _nbx(B)
    elif name == "pairwise":
        sched = _pairwise(B)
    elif name == "hsdx":
        assert boxes is not None, "hsdx needs partition boxes (Lemma 1 adjacency)"
        sched = _hsdx(B, boxes)
    else:
        raise ValueError(f"unknown protocol {name!r}")
    from repro import obs
    if obs.enabled():
        obs.event("protocols.make_schedule",
                  {"protocol": name, "nparts": int(sched.nparts),
                   "n_stages": len(sched.stages),
                   "total_bytes": int(schedule_edge_bytes(sched).sum())})
    return sched


def simulate_delivery(sched: Schedule) -> dict[tuple[int, int], int]:
    """Store-and-forward execution; returns delivered {(origin, dst): bytes}.
    Used by tests to assert every protocol delivers the identical multiset."""
    delivered: dict[tuple[int, int], int] = {}
    for stage in sched.stages:
        for t in stage:
            for (origin, dst, b) in t.payloads:
                if t.dst == dst:
                    delivered[(origin, dst)] = delivered.get((origin, dst), 0) + b
    return delivered


def schedule_edge_bytes(sched: Schedule) -> np.ndarray:
    """Modeled per-edge wire traffic: E[u, v] = bytes rank u sends directly
    to rank v summed over all stages (relayed payloads count at every hop).

    This is the single source of truth the real exchange programs
    (`repro.core.dist.programs`) are built from — tests assert the bytes a
    program's collectives actually carry equal this matrix exactly."""
    E = np.zeros((sched.nparts, sched.nparts), dtype=np.int64)
    for stage in sched.stages:
        for t in stage:
            E[t.src, t.dst] += int(t.nbytes)
    return E


def schedule_stats(sched: Schedule) -> dict:
    msgs = sum(len(st) for st in sched.stages)
    wire_bytes = sum(t.nbytes for st in sched.stages for t in st)
    payload_bytes = sum(b for st in [sched.stages[0]] for t in st for (_, _, b) in t.payloads) if sched.stages else 0
    # payload bytes = unique origin->dst volume (count each payload once)
    seen = set()
    payload_bytes = 0
    for st in sched.stages:
        for t in st:
            for pl in t.payloads:
                if pl not in seen:
                    seen.add(pl)
                    payload_bytes += pl[2]
    max_inbox = 0
    for st in sched.stages:
        per_dst: dict[int, int] = {}
        for t in st:
            per_dst[t.dst] = per_dst.get(t.dst, 0) + 1
        if per_dst:
            max_inbox = max(max_inbox, max(per_dst.values()))
    # n_rounds: device-collective rounds (one ppermute per partial
    # permutation) — the same decomposition the real exchange executes.
    n_rounds = sum(
        len(hsdx_mod.decompose_rounds([(t.src, t.dst) for t in st]))
        for st in sched.stages if st)
    return dict(n_stages=sched.n_stages, n_msgs=msgs, wire_bytes=wire_bytes,
                payload_bytes=payload_bytes, relay_factor=wire_bytes / max(payload_bytes, 1),
                max_msgs_per_dst_stage=max_inbox, n_rounds=n_rounds)


def loggp_time(sched: Schedule, prm: LogGPParams | None = None,
               grain_bytes: int | None = None) -> float:
    """Per-stage critical path: L + max over processes of (send overhead +
    serialization), with the eager/rendezvous cliff; optional grain size
    splits messages (granularity spectrum, Fig 6).

    `prm=None` constructs fresh `LogGPParams` per call — the default is never
    a shared instance, so callers mutating their params cannot leak state
    into other calls."""
    prm = LogGPParams() if prm is None else prm
    total = 0.0
    for stage in sched.stages:
        per_proc: dict[int, float] = {}
        for t in stage:
            n_m, sz = 1, t.nbytes
            if grain_bytes and t.nbytes > grain_bytes:
                n_m = math.ceil(t.nbytes / grain_bytes)
                sz = grain_bytes
            cost = 0.0
            for _ in range(n_m):
                cost += prm.o + sz * prm.G
                if sz > prm.eager_limit:
                    cost += prm.rendezvous_penalty
            per_proc[t.src] = per_proc.get(t.src, 0.0) + cost
        total += prm.L + (max(per_proc.values()) if per_proc else 0.0)
    if sched.name == "nbx":
        total += math.log2(max(sched.nparts, 2)) * (prm.L + prm.o)  # consensus
    return total
