"""Deterministic, shardable, resumable synthetic LM data pipeline.

Tokens are a hash-mixed Markov-ish stream: deterministic in (seed, step,
shard), so (a) every host generates its own shard with zero input I/O —
no input stalls, the straggler story starts from a clean baseline — and
(b) resume-after-restart is exact: the cursor is one integer in the
checkpoint.  A real deployment swaps this class for a file-backed reader
with the same (state, next_batch) contract.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DataState:
    step: int = 0
    seed: int = 0


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_shards: int = 1, shard: int = 0):
        assert global_batch % n_shards == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = global_batch // n_shards
        self.shard = shard
        self.state = DataState(0, seed)

    def next_batch(self):
        s = self.state
        rng = np.random.default_rng(
            np.uint64(hash((s.seed, s.step, self.shard)) & 0xFFFFFFFF))
        # mixture of skewed unigram + local repetition (learnable structure)
        base = rng.zipf(1.5, size=(self.batch, self.seq_len + 1)) % self.vocab
        rep = rng.integers(0, self.vocab, (self.batch, 1))
        mask = rng.random((self.batch, self.seq_len + 1)) < 0.3
        seq = np.where(mask, rep, base).astype(np.int32)
        self.state = DataState(s.step + 1, s.seed)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    # ---- checkpoint contract -------------------------------------------
    def snapshot(self) -> dict:
        return {"step": self.state.step, "seed": self.state.seed}

    def restore(self, snap: dict):
        self.state = DataState(int(snap["step"]), int(snap["seed"]))
