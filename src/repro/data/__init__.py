from repro.data.pipeline import SyntheticLM, DataState  # noqa: F401
