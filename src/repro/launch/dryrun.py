import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh; record memory_analysis, cost_analysis and collective
bytes for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k [--multi-pod] [--out artifacts/]

Artifacts are JSON per cell so the run is resumable and EXPERIMENTS.md is
generated from disk.
"""
import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes
from repro.configs import SHAPES, cell_enabled, get_config, input_specs, list_archs
from repro.configs.base import active_param_count, param_count
from repro.launch.mesh import make_production_mesh, parallelism_for
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import make_train_step


def _micro_batches(cfg, shape, dp_size: int, budget_bytes: float = 2.5e9) -> int:
    """Grad-accumulation microbatches so per-device remat checkpoints fit."""
    layers = cfg.n_layers + cfg.n_enc_layers
    per_layer = shape.global_batch / dp_size * shape.seq_len * cfg.d_model * 2
    n = max(1, math.ceil(per_layer * layers / budget_bytes))
    n = 1 << (n - 1).bit_length()                  # next pow2
    return min(n, shape.global_batch // dp_size * 0 + max(1, shape.global_batch // dp_size))


def batch_shardings(cfg, shape, mesh, par):
    dp = par.data_axes
    specs = {}
    for name, struct in input_specs(cfg, shape).items():
        if name == "pos":
            specs[name] = NamedSharding(mesh, P())
        elif struct.ndim == 2:
            specs[name] = NamedSharding(mesh, P(dp, None))
        else:
            specs[name] = NamedSharding(mesh, P(dp, None, None))
        # long_500k: batch 1 cannot shard over data -> replicate
        if shape.global_batch % par.dp_size() != 0:
            specs[name] = NamedSharding(mesh, P())
    return specs


def cache_shardings(cfg, mesh, par, cache_struct, batch_shardable: bool):
    """Key-path-aware cache shardings: batch over data, cache *sequence* over
    model (sequence-parallel decode attention — softmax stats all-reduce is
    tiny); recurrent states shard their channel dims over model."""
    dp = par.data_axes if batch_shardable else None
    tp = par.model_axis

    def spec_for(path, struct):
        key = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = len(struct.shape)
        if key in ("k", "v", "k_loc", "v_loc"):       # (n_sb, [n_sub,] B, S, n, hd)
            if nd == 6:
                return NamedSharding(mesh, P(None, None, dp, tp, None, None))
            return NamedSharding(mesh, P(None, dp, tp, None, None))  # hymba
        if key in ("k_glob", "v_glob"):               # (n_sb, B, S, n, hd)
            return NamedSharding(mesh, P(None, dp, tp, None, None))
        if key == "wkv":                              # (n_sb, B, H, hd, hd)
            return NamedSharding(mesh, P(None, dp, tp, None, None))
        if key in ("tm_tok", "cm_tok", "conv"):       # (n_sb, B, 1|4, D)
            return NamedSharding(mesh, P(None, dp, None, None))
        if key == "ssm_h":                            # (n_sb, B, D, N)
            return NamedSharding(mesh, P(None, dp, tp, None))
        if key == "memory":                           # (B, S, D)
            return NamedSharding(mesh, P(dp, None, None))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_struct)
    return jax.tree_util.tree_unflatten(treedef,
                                        [spec_for(p, s) for p, s in flat])


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               hierarchical: bool = True, donate: bool = True,
               moe_seq_shard: bool = False, fsdp_pod: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_enabled(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}, None
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = parallelism_for(mesh, hierarchical=hierarchical,
                          moe_seq_shard=moe_seq_shard)
    model = build_model(cfg)
    pstructs = model.param_structs()
    pshard = model.param_shardings(mesh, fsdp_pod=fsdp_pod)
    bshard = batch_shardings(cfg, shape, mesh, par)
    bstructs = input_specs(cfg, shape)
    t0 = time.time()

    if shape.kind == "train":
        n_micro = _micro_batches(cfg, shape, par.dp_size())
        step = make_train_step(model, par, AdamWConfig(), n_micro=n_micro,
                               chunked_attn=shape.seq_len >= 4096
                               and cfg.family not in ("ssm", "hybrid"))
        from repro.train.optimizer import OptState
        ostructs = OptState(
            master=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pstructs),
            m=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pstructs),
            v=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pstructs),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        oshard = OptState(master=pshard, m=pshard, v=pshard,
                          step=NamedSharding(mesh, P()))
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     donate_argnums=(0, 1) if donate else ())
        lowered = fn.lower(pstructs, ostructs, bstructs)
        extra = {"n_micro": n_micro}
    elif shape.kind == "prefill":
        S_max = shape.seq_len + 128
        fn = jax.jit(lambda p, b: model.prefill(p, b, par, S_max),
                     in_shardings=(pshard, bshard))
        lowered = fn.lower(pstructs, bstructs)
        extra = {}
    else:  # decode
        S_max = shape.seq_len
        B = shape.global_batch
        cstruct = model.cache_struct(B, S_max)
        shardable = B % par.dp_size() == 0
        cshard = cache_shardings(cfg, mesh, par, cstruct, shardable)
        tok_struct = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        tok_shard = NamedSharding(mesh, P(par.data_axes if shardable else None, None))
        pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, par),
                     in_shardings=(pshard, cshard, tok_shard, NamedSharding(mesh, P())),
                     donate_argnums=(1,) if donate else ())
        lowered = fn.lower(pstructs, cstruct, tok_struct, pos_struct)
        extra = {}

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    from repro.analysis.hlo_walk import weighted_analysis
    try:
        walked = weighted_analysis(txt)
    except Exception as e:  # keep the artifact even if the walker trips
        walked = {"error": f"{type(e).__name__}: {e}"}
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "hierarchical": hierarchical,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0) if cost else None,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else None,
        "memory": {
            k: getattr(mem, k)
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "generated_code_size_in_bytes")
            if mem is not None and hasattr(mem, k)
        },
        "collectives": coll,
        "walked": walked,
        "params": param_count(cfg),
        "active_params": active_param_count(cfg),
        **extra,
    }
    return result, txt


def save_artifact(path: str, res: dict, hlo_txt: str | None = None):
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    if hlo_txt is not None:
        import gzip
        with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as f:
            f.write(hlo_txt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--flat", action="store_true",
                    help="disable hierarchical (HSDX-style) collectives")
    ap.add_argument("--opt-moe", action="store_true",
                    help="sequence-sharded MoE dispatch (perf hillclimb)")
    ap.add_argument("--fsdp-pod", action="store_true",
                    help="flat ZeRO-3 across pods (vs pod-replicated params "
                         "+ cross-pod grad all-reduce, the default)")
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}__{shape}__{'2pod' if args.multi_pod else '1pod'}"
            if not (args.flat or True):
                pass
            if args.flat:
                tag += "__flat"
            if args.opt_moe:
                tag += "__optmoe"
            if args.fsdp_pod:
                tag += "__fsdppod"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            hlo_txt = None
            try:
                res, hlo_txt = lower_cell(arch, shape, args.multi_pod,
                                          hierarchical=not args.flat,
                                          moe_seq_shard=args.opt_moe,
                                          fsdp_pod=args.fsdp_pod)
            except Exception as e:  # record failures as artifacts too
                res = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-3000:]}
            save_artifact(path, res, hlo_txt)
            status = ("SKIP " + res["skipped"]) if "skipped" in res else \
                ("ERROR " + res["error"][:120]) if "error" in res else \
                (f"ok lower={res['lower_s']}s compile={res['compile_s']}s "
                 f"coll={res['collectives']['total_bytes']/1e9:.2f}GB/dev")
            print(f"[dryrun] {tag}: {status}", flush=True)


if __name__ == "__main__":
    main()
