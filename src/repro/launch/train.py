"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt [--simulate-failure-at 20]

Production posture at 1000+ nodes:
  - checkpoint/restart: atomic sharded saves every --ckpt-every steps; on
    start the driver resumes from the latest step (params, opt state, data
    cursor) — a SIGTERM'd pod restarts exactly where it left off;
  - elastic scaling: checkpoints record full (unsharded) leaf shapes, so a
    restart may load onto a different mesh (tests/test_ckpt.py exercises a
    reshard);
  - straggler mitigation: a per-step deadline — steps that exceed it are
    logged and counted (on real fleets this feeds the health controller that
    evicts slow hosts; the deterministic synthetic pipeline removes input
    stalls entirely);
  - failure injection: --simulate-failure-at N raises mid-run so the restart
    path stays tested.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.sharding.parallel import Parallelism
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def run(arch: str, smoke: bool, steps: int, batch: int, seq: int,
        ckpt_dir: str, ckpt_every: int = 20, lr: float = 3e-4,
        simulate_failure_at: int | None = None, n_micro: int = 1,
        step_deadline_s: float = 120.0, log_every: int = 5,
        seed: int = 0) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    par = Parallelism(remat=False)
    opt_cfg = AdamWConfig(lr=lr, total_steps=max(steps, 10), warmup=min(20, steps // 5 + 1))
    train_step = jax.jit(make_train_step(model, par, opt_cfg, n_micro=n_micro))

    data = SyntheticLM(cfg.vocab, seq, batch, seed=seed)
    start = 0
    last = latest_step(ckpt_dir) if ckpt_dir else None
    if last is not None:
        like = {"params": model.init(jax.random.key(seed)),
                "opt": init_opt_state(model.init(jax.random.key(seed)))}
        state, extra = load_checkpoint(ckpt_dir, last, like)
        params, opt_state = state["params"], state["opt"]
        data.restore(extra["data"])
        start = last
        print(f"[train] resumed from step {start}")
    else:
        params = model.init(jax.random.key(seed))
        opt_state = init_opt_state(params)

    losses, stragglers = [], 0
    for step in range(start, steps):
        if simulate_failure_at is not None and step == simulate_failure_at:
            raise RuntimeError(f"simulated node failure at step {step}")
        t0 = time.time()
        b = data.next_batch()
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, metrics = train_step(params, opt_state, b)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if dt > step_deadline_s:
            stragglers += 1
            print(f"[train] step {step}: STRAGGLER {dt:.1f}s > {step_deadline_s}s")
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            extra={"data": data.snapshot()})
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, {"params": params, "opt": opt_state},
                        extra={"data": data.snapshot()})
    return {"losses": losses, "stragglers": stragglers,
            "final_loss": losses[-1] if losses else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    args = ap.parse_args()
    out = run(args.arch, args.smoke, args.steps, args.batch, args.seq,
              args.ckpt_dir, args.ckpt_every, args.lr,
              args.simulate_failure_at, args.n_micro)
    print(json.dumps({"final_loss": out["final_loss"],
                      "stragglers": out["stragglers"]}))


if __name__ == "__main__":
    main()
