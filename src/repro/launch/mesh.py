"""Production mesh: TPU v5e pods, 256 chips each.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh_compat", "make_production_mesh", "parallelism_for"]


def make_mesh_compat(shape, axes):
    """jax.make_mesh with explicit Auto axis_types on jax >= 0.5, plain mesh
    on older jax (where Auto is the only behavior).  The single home for this
    version shim — tests and production meshes all route through it."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def parallelism_for(mesh, *, hierarchical: bool = True, q_chunk: int = 256,
                    kv_chunk: int = 1024, use_pallas: bool = False,
                    moe_seq_shard: bool = False):
    from repro.sharding.parallel import Parallelism
    multi = "pod" in mesh.axis_names
    return Parallelism(
        mesh=mesh,
        data_axes=("pod", "data") if multi else ("data",),
        model_axis="model",
        pod_axis="pod" if multi else None,
        hierarchical=hierarchical,
        moe_seq_shard=moe_seq_shard,
        q_chunk=q_chunk, kv_chunk=kv_chunk, use_pallas=use_pallas,
    )
