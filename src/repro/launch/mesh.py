"""Production mesh: TPU v5e pods, 256 chips each.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import os
import re

import jax

__all__ = ["make_mesh_compat", "make_production_mesh", "parallelism_for",
           "host_device_mesh", "ensure_host_device_count"]

_HOST_FLAG = "--xla_force_host_platform_device_count"


def _jax_backends_initialized() -> bool:
    """True once any jax computation has forced backend init (after which
    XLA_FLAGS changes are silently ignored by XLA)."""
    try:
        from jax._src import xla_bridge
        return bool(getattr(xla_bridge, "_backends", None))
    except Exception:  # pragma: no cover - private API moved
        return jax.local_device_count() > 1  # best effort; can't tell


def ensure_host_device_count(n: int) -> None:
    """Set `--xla_force_host_platform_device_count=n` in XLA_FLAGS (merging
    with any other flags already present).

    Must run before the first jax computation: XLA reads the flag once at
    backend init.  If backends are already initialized with fewer than `n`
    devices this raises a clear RuntimeError instead of letting callers
    proceed against a silently-ignored flag."""
    if _jax_backends_initialized():
        if jax.local_device_count() >= n:
            return  # already running with enough devices — nothing to do
        raise RuntimeError(
            f"jax is already initialized with {jax.local_device_count()} "
            f"device(s); {_HOST_FLAG}={n} cannot take effect now. Set "
            f"XLA_FLAGS='{_HOST_FLAG}={n}' in the environment (or call "
            f"ensure_host_device_count/host_device_mesh) BEFORE the first "
            f"jax computation, e.g. at the top of your script.")
    flags = os.environ.get("XLA_FLAGS", "")
    flags = re.sub(rf"{_HOST_FLAG}=\d+\s*", "", flags).strip()
    os.environ["XLA_FLAGS"] = f"{flags} {_HOST_FLAG}={n}".strip()


def host_device_mesh(n: int, axis: str = "ranks"):
    """A 1-D mesh of `n` host-platform (CPU) devices for the multi-device
    exchange engine (`repro.core.dist`) and its CPU CI.

    Sets/validates `--xla_force_host_platform_device_count=n`, then builds
    the mesh.  Fails with a clear error when called after jax init with too
    few devices (the flag would be ignored)."""
    ensure_host_device_count(n)
    if jax.local_device_count() < n:
        raise RuntimeError(
            f"requested a {n}-device host mesh but jax initialized only "
            f"{jax.local_device_count()} device(s); is another process "
            f"setting XLA_FLAGS after import?")
    return make_mesh_compat((n,), (axis,))


def make_mesh_compat(shape, axes):
    """jax.make_mesh with explicit Auto axis_types on jax >= 0.5, plain mesh
    on older jax (where Auto is the only behavior).  The single home for this
    version shim — tests and production meshes all route through it."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def parallelism_for(mesh, *, hierarchical: bool = True, q_chunk: int = 256,
                    kv_chunk: int = 1024, use_pallas: bool = False,
                    moe_seq_shard: bool = False):
    from repro.sharding.parallel import Parallelism
    multi = "pod" in mesh.axis_names
    return Parallelism(
        mesh=mesh,
        data_axes=("pod", "data") if multi else ("data",),
        model_axis="model",
        pod_axis="pod" if multi else None,
        hierarchical=hierarchical,
        moe_seq_shard=moe_seq_shard,
        q_chunk=q_chunk, kv_chunk=kv_chunk, use_pallas=use_pallas,
    )
