"""Serving driver: continuous batching over the decode path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --slots 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.parallel import Parallelism


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--s-max", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, B=args.slots, S_max=args.s_max,
                         par=Parallelism(remat=False))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.integers(4, 16))
        engine.submit(Request(rid=rid,
                              prompt=list(rng.integers(1, cfg.vocab, plen)),
                              max_new=args.max_new))
    done = engine.run(max_steps=args.s_max)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s through {args.slots} slots)")


if __name__ == "__main__":
    main()
