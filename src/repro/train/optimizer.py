"""Hand-rolled AdamW with fp32 master weights and global-norm clipping.

Params live in bf16 (forward/backward); the optimizer state keeps fp32
master + first/second moments, all sharded identically to the params (the
ZeRO-3 layout), so the per-device optimizer footprint is params*12B/shards.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    master: Any      # fp32 copies of params
    m: Any
    v: Any
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(jax.tree.map(f32, params), jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params), jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def lr_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_update(grads, opt: OptState, cfg: AdamWConfig, param_dtype=jnp.bfloat16):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt.step + 1
    lr = lr_schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = master - lr * (update + cfg.weight_decay * master)
        return master, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = jax.tree.leaves(opt.master)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    new_ma, new_m, new_v = [], [], []
    for g, ma, m, v in zip(flat_g, flat_ma, flat_m, flat_v):
        a, b, c = upd(g, ma, m, v)
        new_ma.append(a)
        new_m.append(b)
        new_v.append(c)
    master = jax.tree.unflatten(treedef, new_ma)
    params = jax.tree.map(lambda p: p.astype(param_dtype), master)
    new_opt = OptState(master, jax.tree.unflatten(treedef, new_m),
                       jax.tree.unflatten(treedef, new_v), step)
    return params, new_opt, {"grad_norm": gnorm, "lr": lr}
