"""jit-able train step: microbatched grad accumulation + AdamW.

Microbatching bounds the remat live set for the big cells (the per-layer
activation checkpoints scale with B_micro, not B); gradient accumulation
runs as a lax.scan so the HLO stays rolled.  The DP gradient reduction is
either left to GSPMD (flat) or routed through the paper-derived hierarchical
all-reduce (intra-pod reduce-scatter -> inter-pod -> all-gather) — the
`hierarchical` knob measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(model, par, opt_cfg: AdamWConfig = AdamWConfig(),
                    n_micro: int = 1, chunked_attn: bool = False):
    cfg = model.cfg

    def loss_of(params, batch):
        loss, parts = model.loss(params, batch, par, chunked=chunked_attn)
        return loss, parts

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        assert B % n_micro == 0

        if n_micro == 1:
            (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
        else:
            def micro(carry, mb):
                acc, loss_acc = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            def split(x):
                return x.reshape((n_micro, B // n_micro) + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            parts = {}

        new_params, new_opt, metrics = adamw_update(
            grads, opt_state, opt_cfg, param_dtype=jnp.dtype(cfg.dtype))
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step
