"""Error-feedback gradient compression for the DP all-reduce.

int8 stochastic-free quantization with per-tensor scale + local error
feedback (residual carried to the next step), the standard trick for
shrinking inter-pod gradient traffic by 4x when the `pod` axis is the
scarce link — complementary to the hierarchical (HSDX-style) all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, errors):
    """Returns (quantized tree, scales tree, new error-feedback tree)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return q, s, g32 - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    qs, ss, es = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return (jax.tree.unflatten(treedef, list(qs)),
            jax.tree.unflatten(treedef, list(ss)),
            jax.tree.unflatten(treedef, list(es)))


def decompress_tree(qs, ss):
    return jax.tree.map(dequantize_int8, qs, ss)


def init_errors(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
