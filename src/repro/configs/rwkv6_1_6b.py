"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay
[arXiv:2404.05892; unverified].  Heads are d_model/64 (RWKV convention)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab=65536, head_dim=64,
    source="arXiv:2404.05892; unverified",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=32,
)
