"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

from importlib import import_module

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, cell_enabled,
                                input_specs, param_count, active_param_count)

_ARCH_MODULES = {
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "smollm-360m": "smollm_360m",
    "qwen3-0.6b": "qwen3_0_6b",
    "gemma3-12b": "gemma3_12b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "hymba-1.5b": "hymba_1_5b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "dbrx-132b": "dbrx_132b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
