"""seamless-m4t-medium [audio] — encoder-decoder, multimodal
[arXiv:2308.11596; hf].  Backbone only: 12 encoder + 12 decoder layers
("12L" at medium size is per stack — deviation noted in DESIGN.md §9); the
speech frontend is a STUB (input_specs provides precomputed frame embeddings).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64, rope_theta=1e4,
    source="arXiv:2308.11596; hf",
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
)
