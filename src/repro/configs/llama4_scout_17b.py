"""llama4-scout-17b-a16e [moe] — MoE 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Per the assigned config
all layers are MoE with top-1 routing (the HF release interleaves a shared
expert — deviation noted in DESIGN.md §9); early-fusion multimodality is a
frontend concern and out of backbone scope."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, rope_theta=5e5,
    n_experts=16, top_k=1, capacity_factor=1.5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=256, head_dim=16, n_experts=4, top_k=1, capacity_factor=4.0,
)
