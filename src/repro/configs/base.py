"""Config system: architectures (assigned pool) x input shapes.

Every architecture is a `ModelConfig`; every workload cell is a
(ModelConfig, ShapeConfig) pair.  `input_specs()` produces allocation-free
ShapeDtypeStruct stand-ins for the dry-run; smoke tests instantiate the
REDUCED config of the same family.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "input_specs",
           "param_count", "active_param_count"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    # sliding-window pattern: swa_period=6 => 5 local + 1 global (gemma3)
    sliding_window: int = 0     # 0 = none
    swa_period: int = 0
    global_layers: tuple = ()   # explicit global-attention layers (hymba)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    # encoder-decoder
    n_enc_layers: int = 0
    # VLM cross-attention
    cross_attn_period: int = 0  # every Nth layer cross-attends
    n_vis_tokens: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # notes for DESIGN.md / deviations
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / mostly-sliding-window."""
        return self.family in ("ssm", "hybrid") or self.swa_period > 0


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_enabled(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a valid cell; reason when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 512k decode skipped (DESIGN.md)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec:   # audio frontend stub: precomputed frame embeddings
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)
        if cfg.family == "vlm":  # vision frontend stub: patch embeddings
            batch["vis"] = jax.ShapeDtypeStruct((B, cfg.n_vis_tokens, cfg.d_model), bf16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encdec:
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)
        if cfg.family == "vlm":
            batch["vis"] = jax.ShapeDtypeStruct((B, cfg.n_vis_tokens, cfg.d_model), bf16)
        return batch
    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                 "pos": jax.ShapeDtypeStruct((), i32)}
        return batch
    raise ValueError(shape.kind)


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (approximate, matches the built model)."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    qkv = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.family == "ssm":     # rwkv6: time-mix + channel-mix
        per_layer = 4 * d * d + d * f + f * d + 2 * d  # r,k,v,g,o approx + cmix
    else:
        mlp = 3 * d * f         # swiglu
        if cfg.n_experts:
            mlp = cfg.n_experts * 3 * d * f + d * cfg.n_experts
        per_layer = qkv + mlp
        if cfg.family == "hybrid":
            per_layer += 2 * d * cfg.ssm_state + d * d  # ssm head extras
    n_layers = cfg.n_layers + cfg.n_enc_layers
    cross = 0
    if cfg.cross_attn_period:
        cross = (cfg.n_layers // cfg.cross_attn_period) * qkv
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return n_layers * per_layer + cross + emb


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top_k experts count)."""
    if not cfg.n_experts:
        return param_count(cfg)
    d, f = cfg.d_model, cfg.d_ff
    dense_moe_delta = (cfg.n_experts - cfg.top_k) * 3 * d * f * cfg.n_layers
    return param_count(cfg) - dense_moe_delta
