"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, head_dim=128, rope_theta=5e5,
    n_experts=16, top_k=4, capacity_factor=1.25,
    source="hf:databricks/dbrx-base; unverified",
)

SMOKE = ModelConfig(
    name="dbrx-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=256, head_dim=16, n_experts=4, top_k=2, capacity_factor=4.0,
)
