"""gemma3-12b [dense] — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262144, head_dim=256, rope_theta=1e6,
    sliding_window=1024, swa_period=6,      # 5 local : 1 global
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, sliding_window=16, swa_period=6,
    tie_embeddings=True,
)
