"""llama-3.2-vision-90b [vlm] — cross-attn image layers (backbone only; the
vision encoder is a STUB: input_specs provides precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, head_dim=128, rope_theta=5e5,
    cross_attn_period=5, n_vis_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)

SMOKE = ModelConfig(
    name="llama32-vision-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, cross_attn_period=2, n_vis_tokens=8,
)
