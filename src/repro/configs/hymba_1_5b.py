"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every layer;
sliding-window attention except 3 global layers [arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64, rope_theta=1e4,
    ssm_state=16, sliding_window=2048, global_layers=(0, 15, 31),
    source="arXiv:2411.13676; hf",
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, ssm_state=4, sliding_window=16,
    global_layers=(1,),
)
