"""Batched serving engine: continuous batching over a fixed slot grid.

Requests arrive with prompts of varying length; the engine packs them into
B slots, prefills (per-request left-padded into the shared S_max cache) and
decodes one token per step for every live slot, retiring finished slots and
admitting queued requests (slot reuse = continuous batching).  Decode is one
jit'd step — the production path lowered in the decode_* dry-run cells.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.parallel import Parallelism


@dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, B: int = 4, S_max: int = 128,
                 par: Parallelism = Parallelism(remat=False)):
        self.model, self.params, self.B, self.S_max, self.par = \
            model, params, B, S_max, par
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * B
        self.pos = 0
        self.cache = None
        self.finished: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, par))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit_and_prefill(self):
        """Pack queued prompts to a common length and prefill the batch."""
        newly = []
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)
                newly.append(i)
        live = [r for r in self.slots if r is not None]
        if not live:
            return False
        # context = prompt + already-generated tokens (batch-boundary refill
        # must not lose the continuation of still-running requests)
        ctx = {i: (r.prompt + r.out) for i, r in enumerate(self.slots)
               if r is not None}
        L = max(len(c) for c in ctx.values())
        toks = np.zeros((self.B, L), np.int32)
        for i, c in ctx.items():    # right-align so decode position is shared
            toks[i, L - len(c):] = c
        batch = {"tokens": jnp.asarray(toks)}
        self.cache, logits = self.model.prefill(self.params, batch, self.par,
                                                S_max=self.S_max)
        self.pos = L
        tok = jnp.argmax(logits[:, -1, :], axis=-1)
        for i, r in enumerate(self.slots):
            if r is not None:
                r.out.append(int(tok[i]))
                self._retire(i)
        self._next = tok[:, None].astype(jnp.int32)
        return True

    def _retire(self, i):
        r = self.slots[i]
        if r is not None and len(r.out) >= r.max_new:
            r.done = True
            self.finished.append(r)
            self.slots[i] = None    # slot reuse (continuous batching)

    def step(self):
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._next, jnp.int32(self.pos))
        self.pos += 1
        tok = jnp.argmax(logits[:, -1, :], axis=-1)
        self._next = tok[:, None].astype(jnp.int32)
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                continue
            r.out.append(int(tok[i]))
            self._retire(i)

    def run(self, max_steps: int = 64) -> list[Request]:
        if not self._admit_and_prefill():
            return self.finished
        for _ in range(max_steps):
            if all(s is None for s in self.slots):
                if not self.queue:
                    break
                if not self._admit_and_prefill():
                    break
                continue
            if any(s is None for s in self.slots) and self.queue:
                # batch boundary: refill free slots (continuous batching);
                # running requests keep their full context via re-prefill
                if not self._admit_and_prefill():
                    break
                continue
            self.step()
            if self.pos >= self.S_max - 1:
                break
        self.finished.extend(r for r in self.slots if r is not None)
        return self.finished
