"""Parallelism descriptor threaded through the model code.

Mesh conventions (launch/mesh.py):
  single pod : (data=16, model=16)            axes ('data', 'model')
  multi-pod  : (pod=2, data=16, model=16)     axes ('pod', 'data', 'model')

`data_axes` (possibly ('pod','data')) carry DP + FSDP; `model_axis` carries
TP and expert parallelism.  `hierarchical=True` enables the paper-derived
HSDX-style collectives (two-stage grad all-reduce / a2a) where applicable.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["Parallelism"]


@dataclass(frozen=True)
class Parallelism:
    mesh: Any = None                      # jax.sharding.Mesh | None
    data_axes: tuple = ()                 # e.g. ('data',) or ('pod', 'data')
    model_axis: str | None = None
    pod_axis: str | None = None
    hierarchical: bool = True             # HSDX-style collectives
    moe_seq_shard: bool = False           # sequence-shard tokens over TP before
                                          # routing (kills the n_model-times
                                          # replicated dispatch; see §Perf)
    remat: bool = True
    # attention chunking (jnp flash); tuned per shape by launch code
    q_chunk: int = 256
    kv_chunk: int = 1024
    use_pallas: bool = False              # route hot spots through kernels/

    @property
    def dp(self):
        """Spec entry for the batch dimension."""
        return self.data_axes if self.data_axes else None

    @property
    def tp(self):
        return self.model_axis

    def dp_size(self) -> int:
        if not self.mesh or not self.data_axes:
            return 1
        out = 1
        for a in self.data_axes:
            out *= self.mesh.shape[a]
        return out

    def tp_size(self) -> int:
        if not self.mesh or not self.model_axis:
            return 1
        return self.mesh.shape[self.model_axis]

    def constrain(self, x, *spec):
        """with_sharding_constraint when a mesh is active; no-op otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*spec)))


NONE = Parallelism()
