from repro.sharding.parallel import Parallelism  # noqa: F401
