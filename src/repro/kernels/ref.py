"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def p2p_ref(q, x_src, x_tgt):
    """q: (P, S); x_src: (P, S, 3); x_tgt: (P, T, 3) -> (P, T)."""
    d = x_tgt[:, :, None, :] - x_src[:, None, :, :]
    r2 = jnp.sum(d * d, axis=-1)
    inv = jnp.where(r2 > 0, jax.lax.rsqrt(jnp.maximum(r2, 1e-30)), 0.0)
    return jnp.einsum("pts,ps->pt", inv, q)


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / (D ** 0.5)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def wkv_ref(r, k, v, w, u, state):
    """RWKV6 token-by-token oracle.  r/k/v/w: (BH, C, D); u: (BH, D);
    state: (BH, Dk, Dv) -> (y, new_state)."""
    def head(r, k, v, w, u, s0):
        def step(s, inp):
            r_t, k_t, v_t, w_t = inp
            kv = jnp.outer(k_t, v_t)
            y = jnp.sum(r_t[:, None] * (s + u[:, None] * kv), axis=0)
            return w_t[:, None] * s + kv, y
        s1, ys = jax.lax.scan(step, s0.astype(jnp.float32),
                              (r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), w.astype(jnp.float32)))
        return ys, s1
    ys, s1 = jax.vmap(head)(r, k, v, w, u, state)
    return ys.astype(r.dtype), s1.astype(state.dtype)
