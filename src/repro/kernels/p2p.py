"""Pallas TPU kernel: batched P2P Laplace direct sum.

The FMM's compute floor (paper §5: Laplace kernel, Cartesian, P=4) is the
leaf-leaf particle interaction.  For a batch of interaction pairs, each with
up to S sources and T targets:

    phi[p, t] = sum_s q[p, s] / |x_tgt[p, t] - x_src[p, s]|     (self term 0)

TPU adaptation (vs the paper's SIMD CPU loops): targets are tiled into
VMEM-resident blocks of `block_t` lanes (lane-aligned multiples of 128); the
full source block for the pair stays in VMEM across the target tile;
coordinates are laid out structure-of-arrays (3, S) so the subtraction
broadcasts on the VPU's 8x128 registers; the q-weighted reduction runs as an
(block_t, S) x (S,) contraction.  Arithmetic intensity ~ 6 flops / 4 bytes
per (t, s) pair at S=256.

The engine's P2P buckets (repro.core.engine.p2p) arrive with power-of-two
source widths S that vary per bucket; `best_block_t` picks the target block
size per (S, n_pairs) shape class and caches the choice — a one-entry
autotune per bucket shape, measured on real device backends and heuristic
under interpret mode (where wall time is meaningless).
"""
from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.resilience import faults as _faults
from repro.resilience.faults import InjectedFault as _InjectedFault

TB = 128                        # default target block (lane-aligned)
BLOCK_CANDIDATES = (128, 256, 512)
STREAM_BUFFER_CANDIDATES = (2, 3)   # double vs triple buffering (p2p_stream)

# (S, n_pairs, T) -> chosen target block size.  Keyed by the bucket's padded
# shape class, NOT by array identity: every execution of the same geometry
# (and every geometry sharing bucket shapes) reuses one autotune decision.
# T is part of the key — buckets sharing (S, n_pairs) but differing in
# target width need different tilings.
_BLOCK_CACHE: dict[tuple[int, int, int], int] = {}

# (smax, n_rows, wt_max) -> (block_t, n_buffers) for the streaming kernel
# (repro.kernels.p2p_stream): a 2-D autotune space — the VMEM target tile
# AND the DMA pipeline depth — keyed by the unified stream schedule's
# block_t-independent shape class.
_STREAM_CACHE: dict[tuple[int, int, int], tuple[int, int]] = {}

# --- on-disk persistence of MEASURED autotune choices ----------------------
# Measured sweeps (real device backends) are the expensive part of warmup;
# persisting them keyed by (backend, shape class) lets repeat runs — and
# serving fleets — skip the sweep entirely.  Interpret-mode heuristics are
# free to recompute and are never persisted, so CPU test runs touch no disk.
# Opt out with REPRO_P2P_CACHE=0; relocate with REPRO_P2P_CACHE_PATH.
#
# Schema (version 2): {"version": 2, "entries": {backend: {key: value}}}.
# Keys are "S,n,T" (gathered kernel, value = int block_t) or
# "stream:smax,rows,wt" (streaming kernel, value = [block_t, n_buffers]).
# The original unversioned format ({backend: {"S,n,T": int}}) is migrated
# silently on read and rewritten as version 2 on the next save; files with
# an UNKNOWN (future) version are ignored rather than misread as shape keys.
#
# Degradation contract: the disk cache is an optimization, NEVER a
# correctness or liveness dependency.  An unreadable/unwritable location
# (read-only container fs, $HOME on a squashed image, a path under a file)
# warns ONCE, flips the process to in-memory-only operation and never
# touches the disk again — a mid-benchmark run must not crash or spam.
_PERSIST_LOADED = False
_PERSIST_BROKEN = False
_QUARANTINED = False
_SCHEMA_VERSION = 2


def _cache_io_failed(action: str, exc: BaseException) -> None:
    """First disk failure: one RuntimeWarning, then in-memory-only mode."""
    global _PERSIST_BROKEN
    if _PERSIST_BROKEN:
        return
    _PERSIST_BROKEN = True
    from repro.resilience import fallback as _fb
    _fb.record_fallback(f"p2p.cache.{action}", "disk_cache", "in_memory",
                        warn=False)      # the warning below is the warn-once
    import warnings
    warnings.warn(
        f"p2p autotune cache disabled: could not {action} "
        f"{_persist_path()!r} ({exc!r}); continuing with the in-memory "
        f"cache only (set REPRO_P2P_CACHE_PATH to a writable location or "
        f"REPRO_P2P_CACHE=0 to silence)", RuntimeWarning, stacklevel=3)


def _quarantine_corrupt(exc: BaseException) -> None:
    """Corrupt/truncated cache JSON: move the file aside (quarantine) so the
    next save rebuilds a clean one, warn ONCE, and keep running — a damaged
    cache file must never take a session down (it is an optimization, not a
    correctness dependency).  Distinct from `_cache_io_failed`: the location
    is still usable, so persistence stays ON and rebuilds."""
    global _QUARANTINED
    path = _persist_path()
    try:
        os.replace(path, path + ".corrupt")
    except OSError:
        pass                             # racing process already moved it
    from repro import obs
    obs.counter_add("p2p.cache.quarantined")
    if _QUARANTINED:
        return
    _QUARANTINED = True
    import warnings
    warnings.warn(
        f"p2p autotune cache {path!r} is corrupt ({exc!r}); quarantined to "
        f"{path + '.corrupt'!r} and rebuilding from scratch (warns once)",
        RuntimeWarning, stacklevel=3)


def _persist_enabled() -> bool:
    return os.environ.get("REPRO_P2P_CACHE", "1").lower() not in (
        "0", "", "off", "no", "false")


def _persist_path() -> str:
    return os.environ.get("REPRO_P2P_CACHE_PATH") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-fmm",
        "p2p_block_cache.json")


def _parse_entries(data) -> dict:
    """Normalize an on-disk payload to {backend: {key_str: value}}.

    Accepts the current versioned schema AND the original unversioned
    format (silent migration: version 1 was exactly the entries mapping).
    Anything else — including a FUTURE version this build does not
    understand — yields {} so stale processes never misread new keys."""
    if not isinstance(data, dict):
        return {}
    version = data.get("version")
    if version is None:                      # legacy v1: entries at top level
        return {k: v for k, v in data.items() if isinstance(v, dict)}
    if version == _SCHEMA_VERSION:
        entries = data.get("entries", {})
        return entries if isinstance(entries, dict) else {}
    return {}                                # unknown/future schema: ignore


def _load_persisted(backend: str) -> None:
    """Merge this backend's persisted choices into the in-process caches
    (once per process; in-process entries win)."""
    global _PERSIST_LOADED
    if _PERSIST_LOADED:
        return
    _PERSIST_LOADED = True
    try:
        _faults.fire("p2p.cache.read")
        with open(_persist_path()) as f:
            data = json.load(f)
    except FileNotFoundError:
        return                       # cold cache: normal, silent
    except ValueError as exc:        # corrupt/truncated JSON: quarantine it
        _quarantine_corrupt(exc)
        return
    except (OSError, _InjectedFault) as exc:
        # unreadable location (or injected read fault): warn once, degrade
        _cache_io_failed("read", exc)
        return
    for k, v in _parse_entries(data).get(backend, {}).items():
        try:
            if k.startswith("stream:"):
                sm, rows, wt = (int(t) for t in k[len("stream:"):].split(","))
                bt, nb = int(v[0]), int(v[1])
                if bt > 0 and bt % 128 == 0 and nb in STREAM_BUFFER_CANDIDATES:
                    _STREAM_CACHE.setdefault((sm, rows, wt), (bt, nb))
                continue
            S, n, T = (int(t) for t in k.split(","))
            choice = int(v)
        except (TypeError, ValueError, IndexError):
            continue
        # effective_block_t may clamp candidates to any lane-aligned width
        # (e.g. 384), so validate alignment, not membership in CANDIDATES
        if choice > 0 and choice % 128 == 0:
            _BLOCK_CACHE.setdefault((S, n, T), choice)


def _save_persisted(backend: str, key_str: str, value) -> None:
    """Read-merge-write (atomic rename) in the versioned schema — a legacy
    unversioned file is migrated wholesale on the first save.  An unwritable
    location warns once (`_cache_io_failed`) and flips to in-memory-only —
    the cache is an optimization, never a correctness dependency."""
    path = _persist_path()
    try:
        _faults.fire("p2p.cache.write")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        try:
            with open(path) as f:
                entries = _parse_entries(json.load(f))
        except OSError:
            entries = {}
        except ValueError as exc:    # corrupt on the read-merge: quarantine
            _quarantine_corrupt(exc)
            entries = {}
        entries.setdefault(backend, {})[key_str] = value
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump({"version": _SCHEMA_VERSION, "entries": entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except (OSError, _InjectedFault) as exc:
        _cache_io_failed("write", exc)


def _tile_phi(q, xs, xt):
    """One VMEM tile of the Laplace direct sum: q (S,) · xs (3, S) SoA ·
    xt (3, block_t) SoA -> phi (block_t,).  Shared verbatim by the gathered
    kernel below and the streaming kernel (repro.kernels.p2p_stream), which
    is what makes the two paths bitwise-comparable: identical expressions on
    identically shaped tiles."""
    dx = xt[0][:, None] - xs[0][None, :]       # (block_t, S)
    dy = xt[1][:, None] - xs[1][None, :]
    dz = xt[2][:, None] - xs[2][None, :]
    r2 = dx * dx + dy * dy + dz * dz
    inv_r = jnp.where(r2 > 0.0, jax.lax.rsqrt(jnp.maximum(r2, 1e-30)), 0.0)
    return jnp.sum(inv_r * q[None, :], axis=1)


def effective_block_t(T: int, block_t: int) -> int:
    """The target tile width actually worth launching: never wider than the
    128-lane-aligned cover of T.  An autotuned 512 block on a 64-target
    bucket would compute 448 garbage lanes per tile — clamping to the cover
    (128 here) stops paying for them without changing any valid lane."""
    return max(128, min(block_t, ((T + 127) // 128) * 128))


def _p2p_kernel(q_ref, xs_ref, xt_ref, out_ref, *, t_total, block_t):
    # blocks: q (1, S); xs (1, 3, S); xt (1, 3, block_t); out (1, block_t)
    phi = _tile_phi(q_ref[0], xs_ref[0], xt_ref[0])
    if t_total % block_t:
        # partial tail tile: zero the padded lanes (cheap VPU select) so
        # padded targets return 0 instead of garbage
        lane = (pl.program_id(1) * block_t
                + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1)[0])
        phi = jnp.where(lane < t_total, phi, 0.0)
    out_ref[0] = phi


@functools.partial(jax.jit, static_argnames=("interpret", "block_t"))
def p2p_pallas(q, x_src, x_tgt, *, interpret: bool = True,
               block_t: int = TB):
    """q: (P, S); x_src: (P, S, 3); x_tgt: (P, T, 3) -> (P, T).

    Padding convention: padded sources carry q = 0; padded target lanes
    return exactly 0 (the tail tile masks them — the jnp reference's
    garbage rows were always discarded by callers, so only the zeros are
    observable).  `block_t` is the VMEM target tile (lane-aligned multiple
    of 128), clamped to the 128-aligned cover of T (`effective_block_t`)
    so narrow buckets never pay for lanes past their width; pick it with
    `best_block_t` for bucketed shapes.
    """
    if block_t % 128 != 0:
        raise ValueError(f"block_t must be a multiple of 128, got {block_t}")
    P, S, _ = x_src.shape
    T = x_tgt.shape[1]
    block_t = effective_block_t(T, block_t)
    pad_t = (-T) % block_t
    xt = jnp.pad(x_tgt, ((0, 0), (0, pad_t), (0, 0)))
    Tp = T + pad_t
    # structure-of-arrays for lane-friendly broadcast
    xs_t = jnp.swapaxes(x_src, 1, 2)     # (P, 3, S)
    xt_t = jnp.swapaxes(xt, 1, 2)        # (P, 3, Tp)

    out = pl.pallas_call(
        functools.partial(_p2p_kernel, t_total=T, block_t=block_t),
        grid=(P, Tp // block_t),
        in_specs=[
            pl.BlockSpec((1, S), lambda p, t: (p, 0)),
            pl.BlockSpec((1, 3, S), lambda p, t: (p, 0, 0)),
            pl.BlockSpec((1, 3, block_t), lambda p, t: (p, 0, t)),
        ],
        out_specs=pl.BlockSpec((1, block_t), lambda p, t: (p, t)),
        out_shape=jax.ShapeDtypeStruct((P, Tp), q.dtype),
        interpret=interpret,
    )(q, xs_t, xt_t)
    return out[:, :T]


def _heuristic_block_t(S: int, T: int) -> int:
    """Interpret-mode / cold-cache choice: the smallest candidate covering T
    in one tile (fewer grid steps), never exceeding a ~1 MB (3, S)+(block, S)
    VMEM footprint per program (the last fitting candidate wins when all
    covering ones would overflow)."""
    choice = BLOCK_CANDIDATES[0]
    for c in BLOCK_CANDIDATES:
        if (c + 3) * S * 4 > 1 << 20:
            break
        choice = c
        if c >= T:
            break
    return choice


def best_block_t(S: int, n_pairs: int, T: int = TB, *,
                 interpret: bool = True,
                 sample=None) -> int:
    """Autotuned target block size for a P2P bucket shape, cached by
    (S, n_pairs, T).  On a real backend (`interpret=False`) the first call
    for a shape class times every candidate on `sample` (a (q, xs, xt)
    tuple) and keeps the argmin; under interpret mode timing is meaningless,
    so a VMEM heuristic is cached instead.  Measured choices persist to a
    small on-disk JSON keyed (backend, shape class) — see `_persist_path` /
    REPRO_P2P_CACHE — so repeat runs skip the warmup sweep."""
    key = (int(S), int(n_pairs), int(T))
    persist = not interpret and _persist_enabled() and not _PERSIST_BROKEN
    if persist:
        _load_persisted(jax.default_backend())
        persist = not _PERSIST_BROKEN    # load may have just broken it
    from repro import obs
    hit = _BLOCK_CACHE.get(key)
    if hit is not None:
        obs.counter_add("p2p.autotune.cache_hits")
        return hit
    if interpret or sample is None:
        mode = "heuristic"
        choice = _heuristic_block_t(S, T)
    else:
        mode = "measured"
        import statistics
        import time
        q, xs, xt = sample
        # candidates above the 128-aligned cover of T collapse to the same
        # effective tiling (effective_block_t) — time each tiling once
        cands = sorted({effective_block_t(T, c) for c in BLOCK_CANDIDATES})
        best, choice = float("inf"), cands[0]
        for cand in cands:
            fn = lambda: p2p_pallas(q, xs, xt, interpret=False, block_t=cand)
            jax.block_until_ready(fn())          # compile + warm
            reps = []
            for _ in range(3):                   # median rides out one hiccup
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                reps.append(time.perf_counter() - t0)
            dt = statistics.median(reps)
            if dt < best:
                best, choice = dt, cand
        if persist:
            _save_persisted(jax.default_backend(),
                            ",".join(map(str, key)), int(choice))
    _BLOCK_CACHE[key] = choice
    obs.counter_add("p2p.autotune.decisions")
    if obs.enabled():
        obs.event("p2p.autotune",
                  {"S": int(S), "n_pairs": int(n_pairs), "T": int(T),
                   "block_t": int(choice), "mode": mode})
    return choice


def _heuristic_stream_params(smax: int, wt_max: int) -> tuple[int, int]:
    """Interpret-mode / cold-cache choice for the streaming kernel's 2-D
    space.  block_t: smallest candidate covering the widest target class
    (fewer tiles), shrunk until NB=2 buffers of (sources slab + targets +
    phi) fit a ~1 MB VMEM scratch budget.  n_buffers: 2 — triple buffering
    only pays when DMA latency exceeds one tile's compute, which the
    measured sweep (real backends) detects and heuristics can't."""
    nb = 2
    choice = BLOCK_CANDIDATES[0]
    for c in BLOCK_CANDIDATES:
        if nb * (4 * smax + 4 * c) * 4 > 1 << 20:   # (3+1)*SM + (3+1)*bt f32s
            break
        choice = c
        if c >= wt_max:
            break
    return choice, nb


def best_stream_params(smax: int, n_rows: int, wt_max: int, *,
                       interpret: bool = True,
                       measure=None) -> tuple[int, int]:
    """Autotuned (block_t, n_buffers) for the streaming P2P kernel
    (repro.kernels.p2p_stream), cached by the stream schedule's
    block_t-independent shape class (smax, n_rows, wt_max).

    On a real backend the first call sweeps the 2-D candidate grid through
    `measure(block_t, n_buffers) -> seconds` (a caller-supplied closure that
    rebuilds the stream tables for that block and times the kernel) and
    keeps the argmin; under interpret mode a VMEM-budget heuristic is cached
    instead.  Measured choices persist alongside the gathered-kernel entries
    ("stream:" key prefix, versioned schema)."""
    key = (int(smax), int(n_rows), int(wt_max))
    persist = not interpret and _persist_enabled() and not _PERSIST_BROKEN
    if persist:
        _load_persisted(jax.default_backend())
        persist = not _PERSIST_BROKEN
    from repro import obs
    hit = _STREAM_CACHE.get(key)
    if hit is not None:
        obs.counter_add("p2p.autotune.cache_hits")
        return hit
    if interpret or measure is None:
        mode = "heuristic"
        choice = _heuristic_stream_params(smax, wt_max)
    else:
        mode = "measured"
        import statistics
        bt_cands = sorted({effective_block_t(wt_max, c)
                           for c in BLOCK_CANDIDATES})
        best = float("inf")
        choice = (bt_cands[0], STREAM_BUFFER_CANDIDATES[0])
        for bt in bt_cands:
            for nb in STREAM_BUFFER_CANDIDATES:
                reps = [measure(bt, nb) for _ in range(3)]
                dt = statistics.median(reps)
                if dt < best:
                    best, choice = dt, (bt, nb)
        if persist:
            _save_persisted(jax.default_backend(),
                            "stream:" + ",".join(map(str, key)),
                            [int(choice[0]), int(choice[1])])
    _STREAM_CACHE[key] = choice
    obs.counter_add("p2p.autotune.decisions")
    if obs.enabled():
        obs.event("p2p.autotune.stream",
                  {"smax": int(smax), "n_rows": int(n_rows),
                   "wt_max": int(wt_max), "block_t": int(choice[0]),
                   "n_buffers": int(choice[1]), "mode": mode})
    return choice
