"""Pallas TPU kernel: batched P2P Laplace direct sum.

The FMM's compute floor (paper §5: Laplace kernel, Cartesian, P=4) is the
leaf-leaf particle interaction.  For a batch of interaction pairs, each with
up to S sources and T targets:

    phi[p, t] = sum_s q[p, s] / |x_tgt[p, t] - x_src[p, s]|     (self term 0)

TPU adaptation (vs the paper's SIMD CPU loops): targets are tiled into
VMEM-resident blocks of TB=128 (lane-aligned); the full source block for the
pair stays in VMEM across the target tile; coordinates are laid out
structure-of-arrays (3, S) so the subtraction broadcasts on the VPU's 8x128
registers; the q-weighted reduction runs as an (TB, S) x (S,) contraction.
Arithmetic intensity ~ 6 flops / 4 bytes per (t, s) pair at S=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TB = 128  # target block (lane-aligned)


def _p2p_kernel(q_ref, xs_ref, xt_ref, out_ref):
    # blocks: q (1, S); xs (1, 3, S); xt (1, 3, TB); out (1, TB)
    q = q_ref[0]                    # (S,)
    xs = xs_ref[0]                  # (3, S)
    xt = xt_ref[0]                  # (3, TB)
    dx = xt[0][:, None] - xs[0][None, :]       # (TB, S)
    dy = xt[1][:, None] - xs[1][None, :]
    dz = xt[2][:, None] - xs[2][None, :]
    r2 = dx * dx + dy * dy + dz * dz
    inv_r = jnp.where(r2 > 0.0, jax.lax.rsqrt(jnp.maximum(r2, 1e-30)), 0.0)
    out_ref[0] = jnp.sum(inv_r * q[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def p2p_pallas(q, x_src, x_tgt, *, interpret: bool = True):
    """q: (P, S); x_src: (P, S, 3); x_tgt: (P, T, 3) -> (P, T).

    Padding convention: padded sources carry q = 0; padded targets produce
    garbage rows the caller discards (same convention as the jnp reference).
    """
    P, S, _ = x_src.shape
    T = x_tgt.shape[1]
    pad_t = (-T) % TB
    xt = jnp.pad(x_tgt, ((0, 0), (0, pad_t), (0, 0)))
    Tp = T + pad_t
    # structure-of-arrays for lane-friendly broadcast
    xs_t = jnp.swapaxes(x_src, 1, 2)     # (P, 3, S)
    xt_t = jnp.swapaxes(xt, 1, 2)        # (P, 3, Tp)

    out = pl.pallas_call(
        _p2p_kernel,
        grid=(P, Tp // TB),
        in_specs=[
            pl.BlockSpec((1, S), lambda p, t: (p, 0)),
            pl.BlockSpec((1, 3, S), lambda p, t: (p, 0, 0)),
            pl.BlockSpec((1, 3, TB), lambda p, t: (p, 0, t)),
        ],
        out_specs=pl.BlockSpec((1, TB), lambda p, t: (p, t)),
        out_shape=jax.ShapeDtypeStruct((P, Tp), q.dtype),
        interpret=interpret,
    )(q, xs_t, xt_t)
    return out[:, :T]
