"""Pallas TPU kernel: batched MAC (multipole acceptance criterion) scoring.

The dual-tree traversal's only floating-point work is the acceptance test

    margin = theta * |c_A - c_B| - (R_A + R_B)        (accepted iff > 0)

evaluated for every undecided (target, source) cell pair of a frontier
generation.  The device traversal (repro.core.engine.traversal) keeps whole
frontiers in padded `(K,)` arrays, so the score is one lane-parallel launch:
coordinates arrive structure-of-arrays (3, K) — the same VPU-friendly layout
as the P2P kernel — and each grid step scores a 128-lane tile of pairs.

The margin doubles as the traversal's *slack* output: the minimum margin over
accepted M2L pairs is exactly the quantity `api._m2l_margin` recomputes on
the host for `FMMSession.step` MAC-slack revalidation, so the device
traversal returns it for free.

`mac_margins` is trace-safe (no jit of its own): the engine calls it from
inside a `jax.lax.while_loop` body.  `theta` is a Python float baked into the
kernel closure — one compile per theta, shared across every frontier
generation, tree pair and partition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mac_margins", "mac_margins_ref", "MAC_BLOCK"]

MAC_BLOCK = 128                 # lane-aligned pair tile


def _mac_kernel(theta, ca_ref, ra_ref, cb_ref, rb_ref, out_ref):
    # blocks: ca/cb (1, 3, block); ra/rb/out (1, block)
    ca = ca_ref[0]
    cb = cb_ref[0]
    dx = ca[0] - cb[0]
    dy = ca[1] - cb[1]
    dz = ca[2] - cb[2]
    d = jnp.sqrt(dx * dx + dy * dy + dz * dz)
    out_ref[0] = theta * d - (ra_ref[0] + rb_ref[0])


def mac_margins_ref(ca, ra, cb, rb, theta: float):
    """jnp reference: same arithmetic as the kernel body, any K."""
    d = jnp.sqrt(jnp.sum((ca - cb) ** 2, axis=-1))
    return theta * d - (ra + rb)


def mac_margins(ca, ra, cb, rb, theta: float, *, interpret: bool = True,
                block: int = MAC_BLOCK):
    """Score a padded pair frontier in one launch.

    ca/cb: (K, 3) f32 gathered centers; ra/rb: (K,) f32 gathered radii;
    K must be a multiple of `block` (the traversal's frontier capacities are
    powers of two >= 128).  Returns (K,) f32 margins; padded slots produce
    garbage the caller masks.  Trace-safe inside scan/while_loop bodies.
    """
    K = ra.shape[0]
    if K % block != 0:
        raise ValueError(f"frontier length {K} not a multiple of {block}")
    # structure-of-arrays for lane-friendly broadcast (cf. kernels.p2p)
    ca_t = jnp.swapaxes(ca, 0, 1)[None]          # (1, 3, K)
    cb_t = jnp.swapaxes(cb, 0, 1)[None]
    out = pl.pallas_call(
        functools.partial(_mac_kernel, theta),
        grid=(1, K // block),
        in_specs=[
            pl.BlockSpec((1, 3, block), lambda p, t: (p, 0, t)),
            pl.BlockSpec((1, block), lambda p, t: (p, t)),
            pl.BlockSpec((1, 3, block), lambda p, t: (p, 0, t)),
            pl.BlockSpec((1, block), lambda p, t: (p, t)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda p, t: (p, t)),
        out_shape=jax.ShapeDtypeStruct((1, K), ra.dtype),
        interpret=interpret,
    )(ca_t, ra[None], cb_t, rb[None])
    return out[0]
