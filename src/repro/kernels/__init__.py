"""Pallas TPU kernels for the compute hot spots.

  p2p.py        — FMM particle-particle Laplace sum (the paper's compute floor)
  attention.py  — blocked causal flash attention with GQA + sliding window
  rwkv.py       — RWKV6 chunkwise WKV recurrence (state resident in VMEM)

Each kernel is `pl.pallas_call` + explicit BlockSpec VMEM tiling; `ops.py`
exposes jit'd wrappers (interpret mode on CPU, compiled on TPU) and `ref.py`
holds the pure-jnp oracles that gate correctness in tests.
"""
