"""Pallas TPU kernel: blocked causal flash attention (GQA + sliding window).

Online-softmax attention tiled for VMEM: the query block (BQ=128 rows) stays
resident while key/value blocks (BK=128) stream through; running max/sum
rescale the accumulator so nothing spills to HBM.  MXU-aligned contractions
(BQ x D) @ (D x BK) and (BQ x BK) @ (BK x D) with D a multiple of 128
recommended.  GQA is expressed in the BlockSpec index maps (query head h
reads kv head h // group) — no KV replication in HBM.

Sliding-window attention (gemma3 local layers, hymba) masks columns older
than `window` — the kernel grid prunes fully-masked KV blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window, seq_len):
    # q (1, 1, BQ, D); k/v (1, 1, S, D); o (1, 1, BQ, D)
    qb = pl.program_id(2)
    q = q_ref[0, 0] * scale                       # (BQ, D)
    S = k_ref.shape[2]
    D = q.shape[-1]
    q_pos = qb * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)

    def body(i, carry):
        acc, m_i, l_i = carry
        # leading dims via dslice, not bare ints: older pallas can't mix int
        # and Slice indices in one pl.load tuple
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(i * BK, BK), slice(None)))[0, 0]  # (BK, D)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(i * BK, BK), slice(None)))[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)        # (BQ, BK)
        k_pos = i * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        mask = k_pos < seq_len                                          # pad mask
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))                    # (BQ,)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    n_kv = S // BK
    if causal:
        # only blocks at or before the query block contribute
        n_kv = jnp.minimum(n_kv, qb + 1) if isinstance(qb, jax.Array) else min(n_kv, qb + 1)
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (qb * BQ - window) // BK) if isinstance(qb, jax.Array) else max(0, (qb * BQ - window) // BK)
    acc = jnp.zeros((BQ, q.shape[-1]), jnp.float32)
    m_i = jnp.full((BQ,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((BQ,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(lo, n_kv, body, (acc, m_i, l_i))
    o_ref[0, 0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    interpret: bool = True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D); H % Hkv == 0. -> (B, H, S, D)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    scale = 1.0 / (D ** 0.5)
    pad_s = (-S) % max(BQ, BK)
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    Sp = S + pad_s

    kern = functools.partial(_attn_kernel, scale=scale, causal=causal,
                             window=window, seq_len=S)
    out = pl.pallas_call(
        kern,
        grid=(B, H, Sp // BQ),
        in_specs=[
            pl.BlockSpec((1, 1, BQ, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sp, D), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, Sp, D), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BQ, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
