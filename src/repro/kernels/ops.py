"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced Python, validating BlockSpec indexing and numerics; on a
real TPU backend set `interpret=False` (automatic via default_backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import attention as _attn
from repro.kernels import p2p as _p2p
from repro.kernels import rwkv as _rwkv

INTERPRET = jax.default_backend() != "tpu"


def p2p_blocked(q, x_src, x_tgt):
    """Batched pairwise Laplace sum via the Pallas kernel."""
    return _p2p.p2p_pallas(q, x_src, x_tgt, interpret=INTERPRET)


def p2p_auto(q, x_src, x_tgt, *, interpret: bool | None = None):
    """Pallas P2P with a per-bucket-shape autotuned target block size.

    The (S, n_pairs) shape class is looked up in the kernel's autotune cache
    (repro.kernels.p2p.best_block_t): measured once per class on device
    backends, heuristic under interpret mode."""
    interpret = INTERPRET if interpret is None else interpret
    P, S, _ = x_src.shape
    block = _p2p.best_block_t(S, P, x_tgt.shape[1], interpret=interpret,
                              sample=(q, x_src, x_tgt))
    return _p2p.p2p_pallas(q, x_src, x_tgt, interpret=interpret,
                           block_t=block)


def flash_attention(q, k, v, *, causal=True, window=None):
    return _attn.flash_attention(q, k, v, causal=causal, window=window,
                                 interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_wkv(r, k, v, w, u, state, *, chunk: int = 64):
    """Full-sequence RWKV6 WKV: lax.scan over VMEM-resident chunk kernels.

    r/k/v/w: (BH, S, D); u: (BH, D); state: (BH, Dk, Dv).
    Returns (y (BH, S, Dv), final_state).
    """
    BH, S, D = r.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    def body(state, xs):
        rc, kc, vc, wc = xs
        y, state = _rwkv.wkv_chunk(rc, kc, vc, wc, u, state,
                                   interpret=INTERPRET)
        return state, y

    def split(a):
        return jnp.moveaxis(a.reshape(BH, n, chunk, -1), 1, 0)

    state, ys = jax.lax.scan(body, state, (split(r), split(k), split(v), split(w)))
    return jnp.moveaxis(ys, 0, 1).reshape(BH, S, -1), state
