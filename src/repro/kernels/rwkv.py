"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence, chunkwise.

Per head, with state S in R^{Dk x Dv}:

    y_t = sum_i r_t[i] * (S_{t-1}[i, :] + u[i] * k_t[i] * v_t)
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t          (w_t = data-dependent decay)

The GPU implementations keep S in shared memory per block; the TPU analogue
keeps S resident in VMEM for an entire chunk while the per-token loop runs on
the VPU (outer products Dk x Dv), so HBM traffic is one read of (r,k,v,w) and
one write of y per chunk — the recurrence never round-trips the state.
The sequence dimension is chunked by the ops.py wrapper (lax.scan over
pallas_call), giving O(S) work with O(chunk) VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s1_ref):
    # blocks: r/k/v/w (1, C, D); u (1, D); s0 (1, Dk, Dv)
    C, D = r_ref.shape[1], r_ref.shape[2]
    u = u_ref[0]                                   # (Dk,)
    state0 = s0_ref[0].astype(jnp.float32)         # (Dk, Dv)

    def step(t, state):
        # leading dim via dslice, not a bare int: older pallas can't mix int
        # and Slice indices in one pl.load/pl.store tuple
        ix = (pl.dslice(0, 1), pl.dslice(t, 1), slice(None))
        r = pl.load(r_ref, ix)[0, 0].astype(jnp.float32)
        k = pl.load(k_ref, ix)[0, 0].astype(jnp.float32)
        v = pl.load(v_ref, ix)[0, 0].astype(jnp.float32)
        w = pl.load(w_ref, ix)[0, 0].astype(jnp.float32)
        kv = k[:, None] * v[None, :]               # (Dk, Dv) outer product
        y = jnp.sum(r[:, None] * (state + u[:, None] * kv), axis=0)  # (Dv,)
        pl.store(y_ref, ix, y[None, None, :].astype(y_ref.dtype))
        return w[:, None] * state + kv

    state = jax.lax.fori_loop(0, C, step, state0)
    s1_ref[0] = state.astype(s1_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_chunk(r, k, v, w, u, state, *, interpret: bool = True):
    """One chunk. r/k/v/w: (BH, C, D); u: (BH, D); state: (BH, Dk, Dv).
    Returns (y (BH, C, Dv), new_state)."""
    BH, C, D = r.shape
    Dv = v.shape[-1]
    y, s1 = pl.pallas_call(
        _wkv_kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, C, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, Dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, C, D), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, D), lambda b: (b, 0)),
            pl.BlockSpec((1, D, Dv), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, Dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, D, Dv), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, C, Dv), r.dtype),
            jax.ShapeDtypeStruct((BH, D, Dv), state.dtype),
        ],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, s1
