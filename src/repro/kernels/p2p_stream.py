"""Streaming P2P megakernel: in-kernel gather + double-buffered VMEM DMA.

The gathered path (`kernels.p2p` + `engine.p2p._gather_bucket`) makes XLA
materialize every width-class bucket's `(pairs, S, 3)`/`(pairs, S)` operands
in HBM before each `pallas_call` — one full HBM round-trip per bucket per
evaluate, the headline remaining headroom after the PR-6 fused launch.  This
kernel removes the round-trip: it takes the flat device payload and a
scalar-prefetched tile table (`schedules.build_p2p_stream_tables`) and does
the gather *inside* the kernel as slab DMAs into VMEM scratch, pipelined so
tile i+1's slabs stream in while tile i computes — the on-chip analogue of
the paper's overlap-communication-with-computation argument, at DMA
granularity instead of network granularity.

Layout contract (shared with `engine.p2p.stream_payload`):

  payload  (4, F) f32 — structure-of-arrays [x; y; z; q] over the flat body
           axis `F = n_parts * n_bodies_max + pad`.  The `pad` tail rows are
           zero so every fixed-size slab read `[:, start : start + width]`
           stays in bounds; slab lanes past a tile's source count carry
           neighbouring bodies' data and are neutralized by masking q to 0
           (coordinates may be garbage: 1/r of a garbage distance times
           q == 0 contributes exactly +0.0).
  meta     (Ti, 4) int32 — [src_start, src_len, tgt_start, tgt_len] per
           tile, scalar-prefetched to SMEM so DMA addresses for tile i+1
           are known while tile i computes.  Tiles with tgt_len == 0 are
           dead padding: no DMA, no compute, zero output.

Pipelining: `n_buffers` VMEM slots (2 = classic double buffering) rotate
over the grid; step i waits on slot i % NB and starts the slabs for step
i + NB - 1.  The tile body itself is `kernels.p2p._tile_phi` — the same
expression the gathered kernel runs — so on identical slab values the two
paths are bitwise-equal (pinned in tests/test_p2p_stream.py).

Interpret mode runs the same program through the Pallas emulator (DMAs
become copies), which is what CI pins on CPU; `best_stream_params` picks
`(block_t, n_buffers)` per stream shape class on real backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.p2p import _tile_phi

__all__ = ["p2p_stream", "stream_tile_phi"]


def stream_tile_phi(src_slab, tgt_slab, s_len):
    """One streaming tile on (4, smax) / (4, block_t) payload slabs: mask
    charges past `s_len`, then the shared `_tile_phi` body.  Factored so the
    XLA reference path (`engine.p2p`) and tests run the exact expression the
    kernel runs."""
    smax = src_slab.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, smax), 1)[0]
    q = jnp.where(lane < s_len, src_slab[3], 0.0)
    return _tile_phi(q, src_slab[:3], tgt_slab[:3])


def _stream_kernel(meta_ref, pay_ref, out_ref, src_buf, tgt_buf,
                   src_sem, tgt_sem, *, block_t, smax, n_buffers, n_tiles):
    i = pl.program_id(0)

    def slabs(step, slot):
        return (
            pltpu.make_async_copy(
                pay_ref.at[:, pl.ds(meta_ref[step, 0], smax)],
                src_buf.at[slot], src_sem.at[slot]),
            pltpu.make_async_copy(
                pay_ref.at[:, pl.ds(meta_ref[step, 2], block_t)],
                tgt_buf.at[slot], tgt_sem.at[slot]))

    def start(step, slot):
        # dead padding tiles (tgt_len == 0) are pruned: no DMA issued, and
        # the matching wait below is skipped under the same predicate
        @pl.when(meta_ref[step, 3] > 0)
        def _():
            for cp in slabs(step, slot):
                cp.start()

    @pl.when(i == 0)
    def _():                                     # pipeline warmup
        for j in range(min(n_buffers - 1, n_tiles)):
            start(j, j)

    nb = jnp.int32(n_buffers)                    # dtype-pinned (x64-safe)
    nxt = i + n_buffers - 1
    @pl.when(nxt < n_tiles)
    def _():                                     # keep the pipeline full
        start(nxt, jax.lax.rem(jnp.int32(nxt), nb))

    slot = jax.lax.rem(jnp.int32(i), nb)

    @pl.when(meta_ref[i, 3] > 0)
    def _():
        for cp in slabs(i, slot):
            cp.wait()
        out_ref[0] = stream_tile_phi(src_buf[slot], tgt_buf[slot],
                                     meta_ref[i, 1])

    @pl.when(meta_ref[i, 3] == 0)
    def _():
        out_ref[0] = jnp.zeros((block_t,), out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "smax", "n_buffers",
                                             "interpret"))
def p2p_stream(meta, payload, *, block_t: int, smax: int,
               n_buffers: int = 2, interpret: bool = True):
    """meta (Ti, 4) int32, payload (4, F) f32 -> phi (Ti, block_t) f32.

    `payload` must carry at least `max(smax, block_t)` zero rows past the
    last addressable body (`build_p2p_stream_tables`'s `pad`); lanes past a
    tile's tgt_len return the same values the gathered kernel would and are
    masked at accumulation via the stream table's `out_valid`."""
    if block_t % 128 != 0:
        raise ValueError(f"block_t must be a multiple of 128, got {block_t}")
    n_tiles = meta.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, block_t), lambda i, *_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_buffers, 4, smax), jnp.float32),
            pltpu.VMEM((n_buffers, 4, block_t), jnp.float32),
            pltpu.SemaphoreType.DMA((n_buffers,)),
            pltpu.SemaphoreType.DMA((n_buffers,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_stream_kernel, block_t=block_t, smax=smax,
                          n_buffers=n_buffers, n_tiles=n_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, block_t), payload.dtype),
        interpret=interpret,
    )(meta, payload)
