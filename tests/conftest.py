"""Shared fixtures: observability isolation.

The obs subsystem is process-global (module-level tracer + GLOBAL_METRICS),
so counter assertions in one test would see another test's increments
without this autouse reset — tracing is forced off and all recorded
spans/metrics dropped around every test.
"""
import pytest

from repro import obs


@pytest.fixture(autouse=True)
def _obs_isolation():
    obs.configure(enabled=False)
    obs.reset()
    yield
    obs.configure(enabled=False)
    obs.reset()
