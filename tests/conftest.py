"""Shared fixtures: observability + resilience isolation.

The obs subsystem is process-global (module-level tracer + GLOBAL_METRICS),
so counter assertions in one test would see another test's increments
without this autouse reset — tracing is forced off and all recorded
spans/metrics dropped around every test.  The resilience tier keeps the
same kind of process-global state (the armed fault plan and the
fallback/typed-error/retry ledgers), reset the same way.
"""
import pytest

from repro import obs
from repro.resilience import fallback as _res_fb
from repro.resilience import faults as _res_faults


@pytest.fixture(autouse=True)
def _obs_isolation():
    obs.configure(enabled=False)
    obs.reset()
    _res_faults.disarm()
    _res_faults.reset_stats()
    _res_fb.reset_ledger()
    yield
    obs.configure(enabled=False)
    obs.reset()
    _res_faults.disarm()
    _res_faults.reset_stats()
    _res_fb.reset_ledger()
