"""The trip-count-aware HLO walker is the foundation of §Roofline — pin its
exactness on a known module (subprocess with 8 virtual devices)."""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.hlo_walk import weighted_analysis

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 4), ("data", "model"))

    def f(a, w):
        def body(c, _):
            return (c @ w).astype(jnp.float32), None
        y, _ = jax.lax.scan(body, a, None, length=7)
        return y.sum()

    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                 NamedSharding(mesh, P(None, "model")))
                ).lower(a, w).compile()
    res = weighted_analysis(c.as_text())
    # per-device: (256/2 x 512) @ (512 x 512/4), 7 loop trips — EXACT
    expect = 2 * 128 * 512 * 128 * 7
    assert res["dot_flops"] == expect, (res["dot_flops"], expect)
    assert res["total_collective_bytes"] > 0
    assert res["result_bytes"] > 0
    # XLA's own cost_analysis counts the while body ONCE (the bug the
    # walker exists to fix): it must undercount by ~the trip count
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax returns [dict]
        ca = ca[0]
    raw = ca["flops"]
    assert raw < res["dot_flops"] / 3, (raw, res["dot_flops"])
    print("WALK_OK")
""").strip()


def test_walker_exact_on_known_module():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600)
    assert "WALK_OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])


def test_count_entry_launches():
    """Launch counting over compiled HLO: one ENTRY per executable, additive
    over concatenated executables, and zero on StableHLO (`lowered.as_text()`
    has no ENTRY headers — the docstring's feed-compiled-HLO caveat)."""
    import jax
    import jax.numpy as jnp
    from repro.analysis.hlo_walk import count_entry_launches
    sds = jax.ShapeDtypeStruct((8,), jnp.float32)
    lowered = jax.jit(lambda a: a * 2.0 + 1.0).lower(sds)
    hlo = lowered.compile().as_text()
    assert count_entry_launches(hlo) == 1
    assert count_entry_launches(hlo + "\n" + hlo) == 2     # two dispatches
    assert count_entry_launches(lowered.as_text()) == 0    # StableHLO
    assert count_entry_launches("") == 0


def test_collective_byte_parser_units():
    from repro.analysis.hlo_walk import _shape_list, _nbytes
    shapes = _shape_list("bf16[16,1024,128]{2,1,0} f32[8]")
    assert _nbytes(shapes) == 16 * 1024 * 128 * 2 + 8 * 4


def test_pod_crossing_classifier():
    from repro.analysis.hlo_walk import _crosses_pod
    # iota groups of consecutive devices within one pod
    assert not _crosses_pod("all-reduce(%x), replica_groups=[128,2]<=[256]", 256)
    # groups spanning the pod boundary (stride-256 pairs via transpose)
    assert _crosses_pod(
        "all-reduce(%x), replica_groups=[256,2]<=[2,256]T(1,0)", 256)
    # explicit group crossing pods
    assert _crosses_pod("all-gather(%x), replica_groups={{0,256},{1,257}}", 256)
