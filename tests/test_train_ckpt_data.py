"""Training loop, checkpoint/restart (incl. failure injection + elastic
reshard), data pipeline determinism/resume, optimizer behaviour,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, load_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.launch.train import run as train_run
from repro.train import grad_compress as gc
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def test_loss_decreases(tmp_path):
    out = train_run("smollm-360m", smoke=True, steps=30, batch=8, seq=64,
                    ckpt_dir="", lr=3e-3)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_restart_exact(tmp_path):
    d = str(tmp_path / "ck")
    # uninterrupted run
    ref = train_run("qwen3-0.6b", smoke=True, steps=12, batch=4, seq=32,
                    ckpt_dir="", lr=1e-3, seed=7)
    # interrupted at step 6, then resumed
    with pytest.raises(RuntimeError, match="simulated node failure"):
        train_run("qwen3-0.6b", smoke=True, steps=12, batch=4, seq=32,
                  ckpt_dir=d, ckpt_every=3, lr=1e-3, seed=7,
                  simulate_failure_at=7)
    assert latest_step(d) == 6
    resumed = train_run("qwen3-0.6b", smoke=True, steps=12, batch=4, seq=32,
                        ckpt_dir=d, ckpt_every=3, lr=1e-3, seed=7)
    # the resumed trajectory must match the uninterrupted one exactly
    np.testing.assert_allclose(resumed["losses"][-3:], ref["losses"][-3:],
                               rtol=2e-4)


def test_checkpoint_atomic_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, tree, extra={"x": s}, keep=2)
    steps = sorted(os.listdir(d))
    assert steps == ["step_00000004", "step_00000005"]
    got, extra = load_checkpoint(d, 5, tree)
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert extra["x"] == 5


def test_elastic_reshard(tmp_path):
    """Save on one device layout, load onto a 4-device mesh (elastic)."""
    import subprocess, sys, textwrap
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(d, 1, tree)
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import load_checkpoint
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4,), ("data",))
        like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        sh = {{"w": NamedSharding(mesh, P("data", None))}}
        tree, _ = load_checkpoint({d!r}, 1, like, shardings=sh)
        assert len(tree["w"].sharding.device_set) == 4
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("RESHARD_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=300)
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]


def test_data_determinism_and_resume():
    d1 = SyntheticLM(1000, 32, 8, seed=3)
    d2 = SyntheticLM(1000, 32, 8, seed=3)
    b1, b2 = d1.next_batch(), d2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # resume: snapshot after 2 steps and replay
    d1.next_batch()
    snap = d1.snapshot()
    ref = d1.next_batch()
    d3 = SyntheticLM(1000, 32, 8, seed=3)
    d3.restore(snap)
    got = d3.next_batch()
    np.testing.assert_array_equal(ref["tokens"], got["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_sharding_disjoint():
    shards = [SyntheticLM(1000, 16, 8, seed=1, n_shards=4, shard=k)
              for k in range(4)]
    batches = [s.next_batch()["tokens"] for s in shards]
    assert all(b.shape == (2, 16) for b in batches)
    # different shards -> different streams
    assert not np.array_equal(batches[0], batches[1])


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup=1, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(150):
        g = {"w": (opt.master["w"] - target)}
        params, opt, _ = adamw_update(g, opt, cfg, param_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.15)


def test_grad_clip_applied():
    cfg = AdamWConfig(clip_norm=1.0, warmup=1)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw_update(g, opt, cfg)
    assert float(m["grad_norm"]) > 1.0  # reported pre-clip


def test_grad_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    errs = gc.init_errors(g)
    total_deq = jnp.zeros(256)
    total_true = jnp.zeros(256)
    for _ in range(20):
        q, s, errs = gc.compress_tree(g, errs)
        total_deq = total_deq + gc.decompress_tree(q, s)["w"]
        total_true = total_true + g["w"]
    # error feedback: accumulated quantized stream tracks the true sum
    rel = float(jnp.linalg.norm(total_deq - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 5e-3, rel
