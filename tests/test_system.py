"""End-to-end behaviour tests for the paper's system.

The distributed FMM pipeline (hybrid ORB partitioning -> local trees ->
sender-initiated LET -> HSDX exchange -> grafted traversal) must (a) match
the O(N^2) oracle, (b) deliver identical physics under every protocol, and
(c) show the paper's headline structure: neighbor-bounded fan-in + the
boundary-distribution advantage of ORB over Hilbert partitioning."""
import numpy as np
import pytest

from repro.core import protocols as proto
from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution
from repro.core.fmm import direct_potential, fmm_potential


def test_fmm_with_pallas_p2p_kernel():
    """The Pallas P2P kernel slots into the full FMM and matches."""
    n = 1200
    x = make_distribution("sphere", n, seed=21)
    q = np.random.default_rng(2).uniform(-1, 1, n)
    phi_k = fmm_potential(x, q, theta=0.5, ncrit=64, use_pallas=True)
    ref = direct_potential(x, q)
    err = np.linalg.norm(phi_k - ref) / np.linalg.norm(ref)
    assert err < 2e-3, err


def test_protocols_identical_physics():
    n = 1500
    x = make_distribution("ellipsoid", n, seed=4)
    q = np.random.default_rng(4).uniform(-1, 1, n)
    ref_phi = None
    for p in proto.PROTOCOLS:
        res = run_distributed_fmm(x, q, nparts=6, method="orb", protocol=p)
        if ref_phi is None:
            ref_phi = res.phi
        else:
            np.testing.assert_allclose(res.phi, ref_phi, rtol=1e-12)


def test_orb_beats_hilbert_on_boundary_let_volume():
    """Paper 2.2 quantified: the LET the Hilbert partition must ship for a
    sphere exceeds hybrid ORB's."""
    n = 4000
    x = make_distribution("sphere", n, seed=8)
    q = np.ones(n) / n
    r_orb = run_distributed_fmm(x, q, nparts=8, method="orb",
                                protocol="alltoallv", check_delivery=False)
    r_hil = run_distributed_fmm(x, q, nparts=8, method="hilbert",
                                protocol="alltoallv", check_delivery=False)
    assert r_orb.bytes_matrix.sum() < r_hil.bytes_matrix.sum(), (
        r_orb.bytes_matrix.sum(), r_hil.bytes_matrix.sum())


def test_hsdx_grows_advantage_with_scale():
    """Table 3's trend, structurally: alltoallv's per-destination fan-in
    grows linearly with P while HSDX's stays bounded by the neighbor count —
    so the contention ratio grows as partitions are added."""
    n = 4000
    x = make_distribution("sphere", n, seed=12)
    q = np.ones(n) / n
    ratios = []
    for P in (4, 16):
        res = run_distributed_fmm(x, q, nparts=P, method="orb",
                                  protocol="hsdx", check_delivery=False)
        a2a = proto.make_schedule("alltoallv", res.bytes_matrix)
        fan_a2a = proto.schedule_stats(a2a)["max_msgs_per_dst_stage"]
        fan_hsdx = res.schedule_stats["max_msgs_per_dst_stage"]
        assert fan_hsdx <= res.adjacency_degree + 1
        ratios.append(fan_a2a / fan_hsdx)
    assert ratios[1] > ratios[0], ratios
