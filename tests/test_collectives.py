"""Device-level collective patterns, run on 8 virtual host devices in a
subprocess (so the main test process keeps a single device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core import collectives as C

    from repro.launch.mesh import make_mesh_compat as make_mesh

    mesh = make_mesh((8,), ("proc",))
    ok = {}

    x = jnp.arange(8 * 4 * 3, dtype=jnp.float32).reshape(8 * 4, 3)

    # ring all-gather == replicating the full array everywhere
    f = shard_map(lambda s: C.ring_all_gather(s, "proc"), mesh=mesh,
                  in_specs=P("proc"), out_specs=P("proc"))
    got = f(x)  # each shard returns the full (32,3); stacked -> (256, 3)
    ok["ring_all_gather"] = bool(np.allclose(np.asarray(got).reshape(8, 32, 3),
                                             np.broadcast_to(np.asarray(x), (8, 32, 3))))

    # ring reduce-scatter == psum then slice
    f = shard_map(lambda s: C.ring_reduce_scatter(s, "proc"), mesh=mesh,
                  in_specs=P(None), out_specs=P("proc"))
    got = np.asarray(f(x))
    ok["ring_reduce_scatter"] = bool(np.allclose(got, 8 * np.asarray(x)))

    # hierarchical all-reduce over a (pod=2, data=4) mesh == flat psum
    mesh2 = make_mesh((2, 4), ("pod", "data"))
    y = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5)
    f = shard_map(lambda s: C.hierarchical_all_reduce(s, "data", "pod"),
                  mesh=mesh2, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")))
    got = np.asarray(f(y))
    want = np.stack([np.asarray(y).sum(0)] * 8)
    ok["hierarchical_all_reduce"] = bool(np.allclose(got, want))

    # two-stage a2a == flat a2a over the combined axis
    z = jnp.arange(8 * 8 * 2, dtype=jnp.float32).reshape(8, 8, 2)
    f2 = shard_map(lambda s: C.two_stage_all_to_all(s[0], "data", "pod")[None],
                   mesh=mesh2, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")))
    flat = shard_map(lambda s: jax.lax.all_to_all(s[0], ("pod", "data"), 0, 0)[None],
                     mesh=mesh2, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")))
    ok["two_stage_a2a"] = bool(np.allclose(np.asarray(f2(z)), np.asarray(flat(z))))

    # overlapped all-gather matmul == plain (all_gather @ w)
    w = jnp.arange(3 * 7, dtype=jnp.float32).reshape(3, 7) / 10
    f = shard_map(lambda s: C.all_gather_matmul_overlapped(s, w, "proc"),
                  mesh=mesh, in_specs=P("proc"), out_specs=P("proc"))
    got = np.asarray(f(x)).reshape(8, 32, 7)
    want = np.asarray(x) @ np.asarray(w)
    ok["ag_matmul_overlap"] = bool(np.allclose(got, np.broadcast_to(want, (8, 32, 7)), atol=1e-4))

    # neighbor exchange: shift-by-1 ring
    f = shard_map(lambda s: C.neighbor_exchange(s, "proc", 1), mesh=mesh,
                  in_specs=P("proc"), out_specs=P("proc"))
    got = np.asarray(f(jnp.arange(8.0)[:, None])).ravel()
    ok["neighbor_exchange"] = bool(np.allclose(got, np.roll(np.arange(8.0), 1)))

    # hsdx grid exchange on a 2x2x2 grid: one stage delivers all 7 neighbors
    f = shard_map(lambda s: C.hsdx_grid_exchange(s[0], "proc", (2, 2, 2), stages=1)[None],
                  mesh=mesh, in_specs=P("proc"), out_specs=P("proc"))
    got = np.asarray(f(jnp.eye(8)[:, None, :]))      # payload = one-hot rank id
    # every rank must have received every other rank's payload in stage 0
    seen = got.reshape(8, 26, 8).argmax(-1)          # (8, 26) source ranks seen
    ok["hsdx_grid"] = all(set(seen[r]) >= (set(range(8)) - {r}) for r in range(8))

    print(json.dumps(ok))
""").strip()


@pytest.fixture(scope="module")
def collective_results():
    import json as _json
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", "import json\n" + _SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return _json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("name", [
    "ring_all_gather", "ring_reduce_scatter", "hierarchical_all_reduce",
    "two_stage_a2a", "ag_matmul_overlap", "neighbor_exchange", "hsdx_grid",
])
def test_collective(collective_results, name):
    assert collective_results[name], f"{name} failed on 8-device mesh"
