"""Multi-device exchange engine (repro.core.dist).

Host-side invariants (wire layout, round decomposition, program byte
accounting) run in-process; phi parity of the three exchange protocols
against the single-device engine runs on 4 virtual host devices in a
subprocess, so the main test process keeps a single device.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import protocols as proto
from repro.core.api import PartitionSpec, plan_geometry
from repro.core.dist import (DIST_PROTOCOLS, build_exchange_program,
                             build_wire_layout)
from repro.core.hsdx import decompose_rounds

RTOL, ATOL = 1e-6, 2e-5


def _clustered_problem():
    """Duplicated sites -> >= 3 of 8 morton partitions empty (inf/-inf
    sentinel boxes cross the wire)."""
    pts = np.array([[.1, .1, .1], [.8, .2, .3], [.3, .9, .5],
                    [.6, .6, .9], [.9, .9, .1]])
    x = np.repeat(pts, 60, axis=0)
    q = np.random.default_rng(1).uniform(-1, 1, len(x))
    return x, q


def _elongated_geo(nparts=8):
    """Stretched slab: rank adjacency diameter >= 2, so HSDX must relay."""
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 1, (800, 3))
    x[:, 0] *= 4.0
    q = rng.uniform(-1, 1, 800)
    return plan_geometry(x, q, PartitionSpec(nparts=nparts, method="morton",
                                             ncrit=64))


# ------------------------------------------------ round decomposition -----
def test_decompose_rounds_is_partition_of_partial_permutations():
    rng = np.random.default_rng(0)
    for trial in range(20):
        D = int(rng.integers(2, 9))
        edges = {(int(u), int(v)) for u in range(D) for v in range(D)
                 if u != v and rng.random() < 0.5}
        rounds = decompose_rounds(edges)
        flat = [e for rnd in rounds for e in rnd]
        assert sorted(flat) == sorted(edges)          # exact cover, no dupes
        for rnd in rounds:
            srcs = [u for u, _ in rnd]
            dsts = [v for _, v in rnd]
            assert len(set(srcs)) == len(srcs)        # <=1 send per rank
            assert len(set(dsts)) == len(dsts)        # <=1 recv per rank
        # a partial permutation per round => at least max-degree rounds
        if edges:
            deg = np.zeros(D, np.int64)
            for (u, v) in edges:
                deg[u] += 1
            assert len(rounds) >= deg.max()


def test_decompose_rounds_rejects_self_edges():
    with pytest.raises(ValueError):
        decompose_rounds([(1, 1)])


def test_decompose_rounds_matches_schedule_stats():
    """`schedule_stats` n_rounds and the real programs decompose the same
    edge lists — single source of truth."""
    geo = _elongated_geo()
    layout = build_wire_layout(geo, 4)
    for name in ("alltoallv", "hsdx"):
        sched = proto.make_schedule(name, layout.rank_bytes,
                                    boxes=layout.rank_boxes)
        want = sum(len(decompose_rounds([(t.src, t.dst) for t in st]))
                   for st in sched.stages if st)
        assert proto.schedule_stats(sched)["n_rounds"] == want


# ------------------------------------------------------- wire layout -----
def test_wire_layout_bytes_match_geometry_plan():
    """Span word counts x 4 == the frozen `GeometryPlan.bytes_matrix`, and
    rank_bytes is its inter-rank block aggregation with a zero diagonal."""
    x, q = _clustered_problem()
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton",
                                            ncrit=64))
    layout = build_wire_layout(geo, 4)
    B = geo.bytes_matrix
    for (i, j) in layout.pairs:
        assert layout.part_rank[i] != layout.part_rank[j]
        assert layout.span_words[(i, j)] * 4 == B[i, j]
    assert layout.total_words == sum(layout.span_words.values())

    want = np.zeros((4, 4), np.int64)
    for i in range(8):
        for j in range(8):
            ri, rj = layout.part_rank[i], layout.part_rank[j]
            if ri != rj:
                want[ri, rj] += B[i, j]
    np.testing.assert_array_equal(layout.rank_bytes, want)
    assert np.all(np.diag(layout.rank_bytes) == 0)


def test_wire_layout_rejects_uneven_grouping():
    x, q = _clustered_problem()
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton"))
    with pytest.raises(ValueError):
        build_wire_layout(geo, 3)          # 8 % 3 != 0


# ------------------------------------------------- exchange programs -----
def test_program_bytes_equal_modeled_schedule():
    """For every protocol: bytes put on the wire == the Schedule's edge
    bytes (what LogGP costs), and delivered bytes == rank_bytes exactly."""
    geo = _elongated_geo()
    layout = build_wire_layout(geo, 4)
    off = layout.rank_bytes * (1 - np.eye(4, dtype=np.int64))
    for name in DIST_PROTOCOLS:
        prog = build_exchange_program(layout, name)
        np.testing.assert_array_equal(prog.moved_bytes,
                                      proto.schedule_edge_bytes(prog.sched))
        np.testing.assert_array_equal(prog.delivered_bytes, off)
        if name != "hsdx":               # direct protocols never relay
            np.testing.assert_array_equal(prog.moved_bytes,
                                          prog.delivered_bytes)


def test_hsdx_relays_through_neighbors():
    """On a stretched slab the HSDX relay tree moves strictly more bytes
    than it delivers (store-and-forward), in fewer rounds than grain."""
    layout = build_wire_layout(_elongated_geo(), 4)
    prog = build_exchange_program(layout, "hsdx")
    assert prog.moved_bytes.sum() > prog.delivered_bytes.sum()
    assert prog.n_rounds == proto.schedule_stats(prog.sched)["n_rounds"]


def test_grain_rounds_scale_with_grain_bytes():
    layout = build_wire_layout(_elongated_geo(), 4)
    coarse = build_exchange_program(layout, "grain", grain_bytes=8192)
    fine = build_exchange_program(layout, "grain", grain_bytes=2048)
    assert fine.n_rounds > coarse.n_rounds
    np.testing.assert_array_equal(fine.delivered_bytes,
                                  coarse.delivered_bytes)


# ------------------------------------------- 4-device parity subprocess -----
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    from repro.core.api import FMMSession, PartitionSpec, plan_geometry
    from repro.core.engine import DeviceEngine
    from repro.launch.mesh import ensure_host_device_count, host_device_mesh

    mesh = host_device_mesh(4)
    out = {}

    def parity(geo):
        ref = DeviceEngine(geo, use_kernels=False, fused=False).evaluate()
        errs = {}
        for p in ("bulk", "grain", "hsdx"):
            sess = FMMSession(geo, mesh=mesh, dist_protocol=p)
            phi = sess.evaluate()
            ok = bool(np.allclose(phi, ref, rtol=1e-6, atol=2e-5))
            errs[p] = [ok, float(np.max(np.abs(phi - ref)))]
        return errs

    # dense slab: every rank pair talks, HSDX relays
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 1, (800, 3)); x[:, 0] *= 4.0
    q = rng.uniform(-1, 1, 800)
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton",
                                            ncrit=64))
    out["slab"] = parity(geo)

    # duplicated sites: empty partitions (inf/-inf sentinels) on the wire
    pts = np.array([[.1, .1, .1], [.8, .2, .3], [.3, .9, .5],
                    [.6, .6, .9], [.9, .9, .1]])
    x = np.repeat(pts, 60, axis=0)
    q = np.random.default_rng(1).uniform(-1, 1, len(x))
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton",
                                            ncrit=64))
    out["empty_parts"] = [int(p) for p in range(8)
                          if len(geo.owners[p]) == 0]
    out["clustered"] = parity(geo)

    # session-level surfaces: exchange_stats + within-slack step refresh
    sess = FMMSession(geo, mesh=mesh, dist_protocol="bulk")
    st = sess.exchange_stats
    out["stats_keys"] = sorted(st)[:4]
    out["stats_rounds"] = int(st["n_rounds"])

    # asking for more host devices after jax initialised must raise clearly
    try:
        ensure_host_device_count(16)
        out["late_grow"] = "no error"
    except RuntimeError as e:
        out["late_grow"] = "RuntimeError" if "initial" in str(e).lower() \
            or "device" in str(e).lower() else str(e)

    print(json.dumps(out))
""").strip()


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("case", ["slab", "clustered"])
@pytest.mark.parametrize("protocol", DIST_PROTOCOLS)
def test_protocol_phi_parity_on_4_devices(dist_results, case, protocol):
    ok, err = dist_results[case][protocol]
    assert ok, (f"{protocol} phi mismatch vs single-device engine on "
                f"{case}: max abs err {err:.3e}")


def test_sentinels_crossed_the_wire(dist_results):
    assert len(dist_results["empty_parts"]) >= 3


def test_session_exchange_stats(dist_results):
    assert dist_results["stats_rounds"] >= 1


def test_host_device_count_grow_after_init_raises(dist_results):
    assert dist_results["late_grow"] == "RuntimeError"
