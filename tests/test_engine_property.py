"""Hypothesis property sweep for the batched multi-tree upward pass
(repro.core.engine): padded multi-tree P2M/M2M must match the per-partition
reference `upward_pass` for ANY partitioning — ragged depths, ragged sizes,
ragged leaf widths and empty partitions (a None tree is exactly what the
empty-partition inf/-inf box sentinel degenerates to in the geometry plan)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.distributions import make_distribution
from repro.core.engine import build_batched_upward, stack_bodies
from repro.core.engine.upward import batched_upward
from repro.core.fmm import upward_pass
from repro.core.multipole import get_operators
from repro.core.plan import build_tree_schedules
from repro.core.tree import build_tree


@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(16, 64))
@settings(max_examples=8, deadline=None)
def test_batched_upward_matches_per_partition(seed, n_parts, ncrit):
    rng = np.random.default_rng(seed)
    n = 400
    x = make_distribution("plummer", n, seed=seed)
    q = rng.uniform(-1, 1, n)
    part = rng.integers(0, n_parts, n)
    if n_parts > 1:
        part[part == n_parts - 1] = 0      # force at least one empty part
    trees, scheds = [], []
    for p in range(n_parts):
        idx = np.nonzero(part == p)[0]
        if len(idx) == 0:
            trees.append(None)
            scheds.append(None)
            continue
        t = build_tree(x[idx], q[idx], ncrit=ncrit)
        trees.append(t)
        scheds.append(build_tree_schedules(t))
    ops = get_operators(4)
    sched = build_batched_upward(trees, scheds)
    xp, qp = stack_bodies(trees, sched.n_bodies_max)
    M = np.asarray(batched_upward(ops, xp, qp, sched))
    for p, (t, s) in enumerate(zip(trees, scheds)):
        if t is None:
            assert not M[p].any()          # empty partition: exactly zero
            continue
        ref = np.asarray(upward_pass(t, ops, sched=s))
        np.testing.assert_allclose(M[p, :ref.shape[0]], ref,
                                   rtol=1e-6, atol=1e-7)


@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(32, 64))
@settings(max_examples=4, deadline=None)
def test_fused_matches_per_phase_property(seed, n_parts, ncrit):
    """The fused one-launch composite must match the per-phase engine at
    the tight x64 tolerances for ANY geometry the planner produces — ragged
    partitions, ragged bucket sets, m2p present or absent.  Every example is
    its own shape class (an XLA compile), so the example budget stays small;
    x64 keeps both paths on device f64 accumulation."""
    import jax
    from repro.core.api import PartitionSpec, plan_geometry
    from repro.core.engine import DeviceEngine, ExecutableCache
    rng = np.random.default_rng(seed)
    x = make_distribution("plummer", 300, seed=seed)
    q = rng.uniform(-1, 1, 300)
    geo = plan_geometry(x, q, PartitionSpec(nparts=n_parts, ncrit=ncrit))
    jax.config.update("jax_enable_x64", True)
    try:
        want = np.asarray(DeviceEngine(geo, use_kernels=False,
                                       fused=False).evaluate_device())
        got = np.asarray(DeviceEngine(geo, use_kernels=False, fused=True,
                                      exe_cache=ExecutableCache())
                         .evaluate_device())
    finally:
        jax.config.update("jax_enable_x64", False)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=2e-5)


@given(st.integers(0, 10_000), st.integers(1, 3),
       st.sampled_from(["plummer", "sphere"]))
@settings(max_examples=4, deadline=None)
def test_stream_matches_gathered_property(seed, n_parts, dist):
    """The streaming near field (unified tile table + slab gathers,
    repro.kernels.p2p_stream) must match the gathered-bucket engine at the
    tight x64 tolerances for ANY geometry the planner produces — ragged
    width classes, boundary (surface) distributions, empty partitions.  The
    sphere case is the paper's boundary-distribution regime, where leaf
    populations (and therefore stream source widths) are most ragged."""
    import jax
    from repro.core.api import PartitionSpec, plan_geometry
    from repro.core.engine import DeviceEngine
    rng = np.random.default_rng(seed)
    x = make_distribution(dist, 300, seed=seed)
    q = rng.uniform(-1, 1, 300)
    geo = plan_geometry(x, q, PartitionSpec(nparts=n_parts, ncrit=32))
    jax.config.update("jax_enable_x64", True)
    try:
        want = np.asarray(DeviceEngine(geo, use_kernels=False, fused=False,
                                       p2p_stream=False).evaluate_device())
        eng = DeviceEngine(geo, use_kernels=False, fused=False,
                           p2p_stream=True)
        got = np.asarray(eng.evaluate_device())
    finally:
        jax.config.update("jax_enable_x64", False)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=2e-5)


@given(st.integers(0, 5_000))
@settings(max_examples=6, deadline=None)
def test_batched_upward_empty_sentinel_partitions(seed):
    """Partitions made empty by duplicated coordinate clusters (the geometry
    plan's inf/-inf sentinel case) contribute exactly zero rows."""
    from repro.core.api import PartitionSpec, plan_geometry
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (4, 3))
    x = np.repeat(pts, 50, axis=0)
    q = rng.uniform(-1, 1, len(x))
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton",
                                            ncrit=64))
    empty = [p for p in range(8) if len(geo.owners[p]) == 0]
    if not empty:
        return
    for p in empty:
        assert np.all(geo.boxes[p, 0] == np.inf)   # sentinel survives
        assert np.all(geo.boxes[p, 1] == -np.inf)
    sched = build_batched_upward(geo.trees, geo.scheds)
    xp, qp = stack_bodies(geo.trees, sched.n_bodies_max)
    M = np.asarray(batched_upward(get_operators(geo.p), xp, qp, sched))
    for p in empty:
        assert not M[p].any()
    for p in range(8):
        if geo.trees[p] is None:
            continue
        ref = geo.Ms[p]
        np.testing.assert_allclose(M[p, :ref.shape[0]], ref,
                                   rtol=1e-6, atol=1e-7)
