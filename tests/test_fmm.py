"""End-to-end FMM accuracy vs the O(N^2) direct oracle."""
import numpy as np
import pytest

from repro.core.distributions import make_distribution
from repro.core.fmm import direct_potential, fmm_potential
from repro.core.tree import build_tree
from repro.core.traversal import dual_traversal


@pytest.mark.parametrize("dist", ["cube", "sphere"])
def test_fmm_matches_direct(dist):
    n = 2000
    x = make_distribution(dist, n, seed=1)
    q = np.random.default_rng(2).uniform(-1, 1, n)
    phi = fmm_potential(x, q, theta=0.5, ncrit=64)
    ref = direct_potential(x, q)
    err = np.linalg.norm(phi - ref) / np.linalg.norm(ref)
    assert err < 2e-3, f"{dist}: rel err {err}"


def test_fmm_plummer_adaptive():
    n = 1500
    x = make_distribution("plummer", n, seed=3)
    q = np.ones(n) / n
    phi = fmm_potential(x, q, theta=0.4, ncrit=32)
    ref = direct_potential(x, q)
    err = np.linalg.norm(phi - ref) / np.linalg.norm(ref)
    assert err < 2e-3, err


def test_tree_invariants():
    n = 3000
    x = make_distribution("sphere", n, seed=5)
    t = build_tree(x, np.ones(n), ncrit=48)
    # every body in exactly one leaf
    leaves = t.leaves
    total = t.n_body[leaves].sum()
    assert total == n
    # children partition the parent's body range
    for c in range(t.n_cells):
        if t.n_child[c]:
            cs, nc = t.child_start[c], t.n_child[c]
            assert t.n_body[cs:cs + nc].sum() == t.n_body[c]
            assert t.body_start[cs] == t.body_start[c]
        # tight bbox: center/radius consistent with bounds
        assert np.all(t.bbox_min[c] <= t.bbox_max[c])
    # tight boxes nest within parents
    for c in range(1, t.n_cells):
        p = t.parent[c]
        assert np.all(t.bbox_min[c] >= t.bbox_min[p] - 1e-12)
        assert np.all(t.bbox_max[c] <= t.bbox_max[p] + 1e-12)


def test_traversal_covers_all_pairs():
    """Every (target leaf body, source leaf body) pair is covered exactly once
    by either an M2L ancestor pair or a P2P leaf pair."""
    n = 600
    x = make_distribution("cube", n, seed=7)
    t = build_tree(x, np.ones(n), ncrit=24)
    m2l, p2p = dual_traversal(t, t, theta=0.5)

    def descendant_leaves(c):
        out, stack = [], [c]
        while stack:
            k = stack.pop()
            if t.n_child[k] == 0:
                out.append(k)
            else:
                stack.extend(range(t.child_start[k], t.child_start[k] + t.n_child[k]))
        return out

    nl = len(t.leaves)
    leaf_pos = {c: i for i, c in enumerate(t.leaves)}
    cover = np.zeros((nl, nl), dtype=np.int32)
    for a, b in np.concatenate([m2l, p2p]):
        for la in descendant_leaves(a):
            for lb in descendant_leaves(b):
                cover[leaf_pos[la], leaf_pos[lb]] += 1
    assert (cover == 1).all(), "interaction coverage must be exact and unique"
