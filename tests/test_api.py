"""Three-layer API (repro.core.api): GeometryPlan -> CommSchedule ->
FMMSession.  Golden equivalence of the legacy shims, single-extraction
protocol sweeps, device-view memoization, MAC-slack timestep revalidation,
and the empty-partition / LogGP-params satellite regressions."""
import warnings

import numpy as np
import pytest

import repro.core.api as api
import repro.core.distributed_fmm as dfmm
from repro.core import protocols as proto
from repro.core.api import (FMMSession, PartitionSpec, plan_geometry,
                            schedule_comm)
from repro.core.distributed_fmm import (build_distributed_plan,
                                        execute_distributed_plan,
                                        run_distributed_fmm)
from repro.core.distributions import make_distribution
from repro.core.fmm import direct_potential
from repro.core.hsdx import adjacency_from_boxes


def _problem(n=1500, seed=5, qseed=6):
    x = make_distribution("sphere", n, seed=seed)
    q = np.random.default_rng(qseed).uniform(-1, 1, n)
    return x, q


# ------------------------------------------------- layering / plan reuse ---
def test_sweep_bitwise_identical_to_independent_runs():
    """One GeometryPlan serving all four protocols must reproduce four
    independent legacy runs bit for bit — potential AND accounting."""
    x, q = _problem()
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=5, ncrit=48))
    sweep = sess.sweep()
    assert set(sweep) == set(proto.PROTOCOLS)
    for name in proto.PROTOCOLS:
        res = run_distributed_fmm(x, q, nparts=5, method="orb",
                                  protocol=name, theta=0.5, ncrit=48)
        assert np.array_equal(sweep[name].phi, res.phi), name
        assert np.array_equal(sweep[name].bytes_matrix, res.bytes_matrix)
        assert sweep[name].schedule_stats == res.schedule_stats, name
        assert sweep[name].loggp_time == res.loggp_time
        assert sweep[name].n_stages == res.n_stages


def test_sweep_extracts_lets_exactly_once_per_sender(monkeypatch):
    """The acceptance criterion: sweeping all four protocols performs exactly
    one (batched) extract_lets call per sender — zero re-extraction."""
    x, q = _problem(n=1200)
    nparts = 4
    calls = []
    real = api.extract_lets
    monkeypatch.setattr(api, "extract_lets",
                        lambda *a, **k: calls.append(a) or real(*a, **k))
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=nparts, ncrit=48))
    sess.sweep()
    assert len(calls) == nparts
    # ... and each call batched all P-1 remote boxes of its sender
    assert all(len(a[2]) == nparts - 1 for a in calls)


def test_schedule_comm_pure_over_frozen_geometry():
    x, q = _problem(n=1000)
    geo = plan_geometry(x, q, PartitionSpec(nparts=4, ncrit=48))
    B = geo.bytes_matrix.copy()
    for name in proto.PROTOCOLS:
        cs = schedule_comm(geo, name)
        assert cs.n_stages >= 1
        delivered = proto.simulate_delivery(cs.schedule)
        assert sum(delivered.values()) == B[B > 0].sum()
    assert np.array_equal(geo.bytes_matrix, B)   # geometry untouched


# ------------------------------------------------------- legacy shims ------
def test_legacy_shims_byte_identical_to_layered_path():
    x, q = _problem(n=1200)
    spec = PartitionSpec(nparts=4, ncrit=48)
    sess = FMMSession.from_points(x, q, spec)
    res_new = sess.potentials("hsdx")

    res_old = run_distributed_fmm(x, q, nparts=4, method="orb",
                                  protocol="hsdx", theta=0.5, ncrit=48)
    assert np.array_equal(res_old.phi, res_new.phi)
    assert np.array_equal(res_old.bytes_matrix, res_new.bytes_matrix)
    assert res_old.schedule_stats == res_new.schedule_stats
    assert res_old.loggp_time == res_new.loggp_time

    plan = build_distributed_plan(x, q, nparts=4, method="orb",
                                  protocol="hsdx", theta=0.5, ncrit=48)
    assert np.array_equal(execute_distributed_plan(plan), res_new.phi)
    assert np.array_equal(plan.bytes_matrix, res_new.bytes_matrix)


def test_legacy_shims_warn_exactly_once():
    """Runs clean even under `-W error::DeprecationWarning` (CI exercises
    that filter): the shims warn once per process, and this test scopes the
    filter so the warning is recorded, not raised."""
    x, q = _problem(n=400)
    dfmm._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        run_distributed_fmm(x, q, nparts=2, ncrit=48)
        run_distributed_fmm(x, q, nparts=2, ncrit=48)
        build_distributed_plan(x, q, nparts=2, ncrit=48)
        build_distributed_plan(x, q, nparts=2, ncrit=48)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "repro.core.api" in str(w.message)]
    assert len(dep) == 2          # one per entry point, despite two calls each
    names = sorted(str(w.message).split(" ")[0] for w in dep)
    assert names == ["build_distributed_plan", "run_distributed_fmm"]


# --------------------------------------------------- device-view memo ------
def test_repeat_execution_zero_host_device_transfers():
    """Acceptance criterion: after the first execution, every frozen plan
    table is served from the memoized device view — zero new uploads."""
    x, q = _problem(n=1000)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48))
    phi1 = sess.evaluate()
    assert sess.memo.misses > 0           # first run uploaded the tables
    misses0 = sess.memo.misses
    phi2 = sess.evaluate()
    assert sess.memo.misses == misses0    # second run: zero transfers
    assert sess.memo.hits > 0
    assert np.array_equal(phi1, phi2)
    # the cached potential is shared across SessionResults: read-only
    assert not phi1.flags.writeable
    with pytest.raises(ValueError):
        phi1[0] = 0.0


def test_asarray_hook_must_return_device_array():
    """DeviceMemo contract (documented on the class): an `asarray=` hook
    returning a NumPy array would silently re-upload every table on every
    kernel call — the executors must raise a clear TypeError instead."""
    from repro.core.fmm import upward_pass
    from repro.core.multipole import get_operators
    x, q = _problem(n=300)
    geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=48))

    def numpy_hook(arr, dtype=None):       # violates the device-array contract
        return np.asarray(arr, dtype=dtype)

    with pytest.raises(TypeError, match="device array"):
        api.execute_geometry(geo, asarray=numpy_hook)
    with pytest.raises(TypeError, match="re-upload"):
        upward_pass(geo.trees[0], get_operators(geo.p),
                    sched=geo.scheds[0], asarray=numpy_hook)
    # the real memo satisfies the contract end to end
    phi = api.execute_geometry(geo, asarray=api.DeviceMemo())
    assert np.isfinite(phi).all()


def test_device_memo_evicts_replaced_arrays_across_steps():
    """Long-running sessions must not leak device views: arrays replaced by
    a step (positions, multipoles, LET payloads) self-evict from the memo
    once the old geometry is dropped; shared index tables stay cached."""
    import gc
    x, q = _problem(n=1000)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48))
    sess.evaluate()
    eps = float(sess.geometry.slack.min())
    rng = np.random.default_rng(1)
    sizes = []
    for _ in range(3):
        sess.step(sess.geometry.x0
                  + rng.uniform(-eps / 8, eps / 8, size=x.shape))
        sess.evaluate()
        gc.collect()
        sizes.append(len(sess.memo))
    assert sizes[1] == sizes[2]           # steady state, not linear growth


# ----------------------------------------------------------- stepping ------
def test_step_unmoved_is_full_cache_hit():
    x, q = _problem(n=1200)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48))
    phi1 = sess.potentials("hsdx").phi
    geo0 = sess.geometry
    rep = sess.step(x.copy())
    assert rep.cache_hit and rep.rebuilt == () and rep.refreshed == ()
    assert sess.geometry is geo0          # no tree rebuilds, no new version
    assert np.array_equal(sess.evaluate(), phi1)   # bitwise re-execution


def test_step_within_slack_refreshes_without_rebuild():
    x, q = _problem(n=1500)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48))
    sess.potentials("hsdx")
    geo0 = sess.geometry
    eps = float(geo0.slack.min())
    assert eps > 0
    rng = np.random.default_rng(0)
    x1 = x + rng.uniform(-eps / 4, eps / 4, size=x.shape)   # |dx| < slack
    rep = sess.step(x1)
    assert rep.rebuilt == ()
    assert len(rep.refreshed) == 4
    # structure is shared: same index arrays, same interaction plans
    for j in range(4):
        assert sess.geometry.trees[j].parent is geo0.trees[j].parent
        assert sess.geometry.receivers[j].local is geo0.receivers[j].local
    phi = sess.potentials("hsdx").phi
    ref = direct_potential(x1, q)
    assert np.linalg.norm(phi - ref) / np.linalg.norm(ref) < 3e-3


def test_step_rebuilds_only_invalidated_partitions():
    x, q = _problem(n=1500)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48))
    sess.potentials("hsdx")
    geo0 = sess.geometry
    mover = 2
    x1 = x.copy()
    x1[geo0.owners[mover]] += np.array([0.15, -0.1, 0.2])   # >> slack
    rep = sess.step(x1)
    assert rep.rebuilt == (mover,)
    assert rep.refreshed == ()
    for j in range(4):                    # untouched partitions reused as-is
        if j != mover:
            assert sess.geometry.trees[j] is geo0.trees[j]
    phi = sess.potentials("hsdx").phi
    ref = direct_potential(x1, q)
    assert np.linalg.norm(phi - ref) / np.linalg.norm(ref) < 3e-3


def test_step_rejects_mismatched_shapes():
    x, q = _problem(n=600)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=2, ncrit=48))
    with pytest.raises(ValueError, match="positions"):
        sess.step(x[:100])
    with pytest.raises(ValueError, match="charges"):
        sess.step(x.copy(), q[:100])


def test_step_charge_update_refreshes_multipoles():
    x, q = _problem(n=1000)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48))
    sess.potentials("hsdx")
    q2 = q * 1.7
    rep = sess.step(x.copy(), q2)
    assert rep.rebuilt == () and len(rep.refreshed) == 4
    phi = sess.potentials("hsdx").phi
    ref = direct_potential(x, q2)
    assert np.linalg.norm(phi - ref) / np.linalg.norm(ref) < 3e-3


# ------------------------------------------------- satellite regressions ---
def test_empty_partitions_use_sentinel_and_stay_correct():
    """A partition with no bodies must not contribute a [0,0]-at-origin box
    to the Lemma-1 adjacency graph or receive/send LETs."""
    pts = np.array([[.1, .1, .1], [.8, .2, .3], [.3, .9, .5],
                    [.6, .6, .9], [.9, .9, .1]])
    x = np.repeat(pts, 60, axis=0)        # 5 sites -> >= 3 of 8 parts empty
    q = np.random.default_rng(1).uniform(-1, 1, len(x))
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton",
                                            ncrit=64))
    empty = [p for p in range(8) if len(geo.owners[p]) == 0]
    assert len(empty) >= 3
    for p in empty:
        assert np.all(geo.boxes[p, 1] < geo.boxes[p, 0])       # sentinel
        assert geo.trees[p] is None and geo.receivers[p] is None
        assert geo.bytes_matrix[p].sum() == 0
        assert geo.bytes_matrix[:, p].sum() == 0
    adj = adjacency_from_boxes(geo.adj_boxes)
    assert all(len(adj[p]) == 0 for p in empty)                # isolated
    assert all(p not in a for p in empty for a in adj)
    sess = FMMSession(geo)
    phi = sess.potentials("hsdx").phi
    ref = direct_potential(x, q)
    assert np.linalg.norm(phi - ref) / np.linalg.norm(ref) < 3e-3


@pytest.mark.parametrize("n,nparts", [(3, 5), (1, 4), (2, 8)])
def test_orb_more_parts_than_points_gets_sentinel(n, nparts):
    """Empty branches must carry sentinels even when they reach *internal*
    recursion nodes (e.g. 1 point split 4 ways routes an empty half into a
    2-part subtree)."""
    from repro.core.partition.orb import orb_partition
    x = np.random.default_rng(0).uniform(size=(n, 3))
    part, boxes = orb_partition(x, nparts)
    assert len(np.unique(part)) == n
    empty = [p for p in range(nparts) if (part == p).sum() == 0]
    assert len(empty) == nparts - n
    for p in empty:
        assert np.all(boxes[p, 1] < boxes[p, 0])


def test_loggp_params_default_not_shared():
    """protocols.loggp_time must construct fresh LogGPParams per call —
    mutating a caller-owned instance cannot leak into the default path."""
    import inspect
    assert inspect.signature(proto.loggp_time).parameters["prm"].default is None
    B = np.zeros((2, 2), dtype=np.int64)
    B[0, 1] = 64 * 1024
    s = proto.make_schedule("alltoallv", B)
    base = proto.loggp_time(s)
    prm = proto.LogGPParams()
    prm.o *= 100.0
    assert proto.loggp_time(s, prm=prm) > base
    assert proto.loggp_time(s) == base    # default unaffected by the mutation
