"""Serving engine: continuous batching, slot reuse, decode == forward."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.parallel import Parallelism


def test_engine_serves_queue_through_slots():
    cfg = get_config("smollm-360m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, B=2, S_max=64,
                      par=Parallelism(remat=False))
    rng = np.random.default_rng(1)
    for rid in range(4):  # 4 requests through 2 slots
        eng.submit(Request(rid=rid,
                           prompt=list(rng.integers(1, cfg.vocab, 6)),
                           max_new=4))
    done = eng.run(max_steps=40)
    assert len(done) == 4
    for r in done:
        assert len(r.out) >= 4
        assert all(0 <= t for t in r.out)


def test_greedy_decode_matches_forward_argmax():
    """Engine's greedy continuation equals argmax over the growing sequence
    computed with the plain forward pass (cache correctness end-to-end)."""
    cfg = get_config("qwen3-0.6b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    par = Parallelism(remat=False)
    prompt = [3, 17, 91, 45]
    eng = ServeEngine(model, params, B=1, S_max=32, par=par)
    eng.submit(Request(rid=0, prompt=prompt, max_new=5))
    out = eng.run(max_steps=10)[0].out

    # reference: repeated full forward + argmax
    from repro.models.transformer import logits_fn
    seq = list(prompt)
    want = []
    for _ in range(5):
        h, _ = model.forward(params, {"tokens": jnp.asarray([seq], jnp.int32)}, par)
        tok = int(jnp.argmax(logits_fn(params, h[:, -1:], cfg, par)[0, -1]))
        want.append(tok)
        seq.append(tok)
    assert out == want, (out, want)
