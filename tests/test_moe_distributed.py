"""MoE expert-parallel path: shard_map a2a vs the dense oracle, on 4 virtual
devices in a subprocess (the main process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.moe import moe_ffn, _moe_dense, moe_defs
    from repro.models.params import init_params
    from repro.sharding.parallel import Parallelism
    from dataclasses import replace

    cfg = get_config("dbrx-132b", smoke=True)      # 4 experts top-2
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    par = Parallelism(mesh=mesh, data_axes=("data",), model_axis="model",
                      remat=False)
    p = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                          jnp.float32) * 0.3

    y_ep, aux_ep = jax.jit(lambda x, p: moe_ffn(x, p, cfg, par))(x, p)
    y_ref, aux_ref = _moe_dense(x, p, cfg)
    # NOTE: EP computes capacity per data shard (2 tokens-groups), the dense
    # oracle over the full batch; with capacity_factor=4 nothing drops, so
    # the outputs must match exactly.
    err = float(jnp.max(jnp.abs(y_ep - y_ref)))
    print("MOE_MAX_ERR", err)
    assert err < 1e-4, err
    # gradients flow through the a2a
    g = jax.grad(lambda p: jnp.sum(moe_ffn(x, p, cfg, par)[0] ** 2))(p)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    print("MOE_GRAD_NORM", gn)
    assert gn > 0
    print("MOE_OK")
""").strip()


def test_moe_shard_map_matches_dense_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600)
    assert "MOE_OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])
