"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------- p2p ------
@pytest.mark.parametrize("P,S,T", [(1, 64, 64), (3, 128, 100), (2, 32, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_p2p_kernel_matches_ref(P, S, T, dtype):
    rng = np.random.default_rng(P * 1000 + S + T)
    q = jnp.asarray(rng.uniform(-1, 1, (P, S)), dtype)
    xs = jnp.asarray(rng.uniform(-1, 1, (P, S, 3)), dtype)
    xt = jnp.asarray(rng.uniform(-1, 1, (P, T, 3)), dtype)
    got = ops.p2p_blocked(q, xs, xt)
    want = ref.p2p_ref(q, xs, xt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_p2p_kernel_self_pair_zero_diag():
    """Targets == sources: the r=0 self term contributes exactly 0."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1, 1, (1, 64, 3)), jnp.float32)
    q = jnp.ones((1, 64), jnp.float32)
    got = ops.p2p_blocked(q, x, x)
    want = ref.p2p_ref(q, x, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)
    assert np.all(np.isfinite(np.asarray(got)))


def test_p2p_padded_sources_ignored():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.uniform(-1, 1, (2, 64)), jnp.float32).at[:, 40:].set(0.0)
    xs = jnp.asarray(rng.uniform(-1, 1, (2, 64, 3)), jnp.float32)
    xt = jnp.asarray(rng.uniform(2, 3, (2, 16, 3)), jnp.float32)
    got = ops.p2p_blocked(q, xs, xt)
    want = ref.p2p_ref(q[:, :40], xs[:, :40], xt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------- attention ------
@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA group 2
    (1, 8, 2, 128, 128),    # GQA group 4, MXU-aligned D
    (1, 2, 1, 200, 64),     # ragged seq (padding path)
])
def test_flash_attention_matches_ref(B, H, Hkv, S, D):
    rng = np.random.default_rng(S + D)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(window)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)


# --------------------------------------------------------------- rwkv ------
@pytest.mark.parametrize("BH,S,D,chunk", [(2, 128, 64, 64), (4, 64, 32, 32),
                                          (1, 256, 64, 128)])
def test_wkv_matches_ref(BH, S, D, chunk):
    rng = np.random.default_rng(S * D)
    r = jnp.asarray(rng.normal(size=(BH, S, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, S, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, S, D)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.8, 0.999, (BH, S, D)), jnp.float32)  # decay
    u = jnp.asarray(rng.normal(size=(BH, D)) * 0.1, jnp.float32)
    s0 = jnp.zeros((BH, D, D), jnp.float32)
    y_got, s_got = ops.rwkv6_wkv(r, k, v, w, u, s0, chunk=chunk)
    y_want, s_want = ref.wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=1e-4, atol=1e-4)


def test_wkv_chunk_invariance():
    """Chunk size must not change the result (the granularity knob again)."""
    rng = np.random.default_rng(3)
    args = [jnp.asarray(rng.normal(size=(2, 128, 32)) * 0.3, jnp.float32)
            for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.9, 0.999, (2, 128, 32)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(2, 32)) * 0.1, jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(2, 32, 32)) * 0.1, jnp.float32)
    y32, s32 = ops.rwkv6_wkv(args[0], args[1], args[2], w, u, s0, chunk=32)
    y128, s128 = ops.rwkv6_wkv(args[0], args[1], args[2], w, u, s0, chunk=128)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s32), np.asarray(s128), rtol=1e-5, atol=1e-5)


# ------------------------------------------------- autotune persistence ----
def _fake_measured_autotune(monkeypatch, tmp_path):
    """Route best_block_t's measured sweep through a fake kernel + clock
    (real interpret=False compiles are impossible on CPU) and a tmp cache
    file."""
    import time as _time
    from repro.kernels import p2p as kp
    monkeypatch.setenv("REPRO_P2P_CACHE_PATH", str(tmp_path / "cache.json"))
    monkeypatch.delenv("REPRO_P2P_CACHE", raising=False)
    monkeypatch.setattr(kp, "_BLOCK_CACHE", {})
    monkeypatch.setattr(kp, "_STREAM_CACHE", {})
    monkeypatch.setattr(kp, "_PERSIST_LOADED", False)
    monkeypatch.setattr(kp, "_PERSIST_BROKEN", False)
    calls = []
    clock = iter(np.arange(0.0, 1000.0, 0.5))

    def fake_pallas(q, xs, xt, *, interpret, block_t):
        calls.append(block_t)
        return jnp.zeros((xt.shape[0], xt.shape[1]), jnp.float32)

    monkeypatch.setattr(kp, "p2p_pallas", fake_pallas)
    monkeypatch.setattr(_time, "perf_counter", lambda: next(clock))
    return kp, calls


def test_autotune_persists_measured_choice(monkeypatch, tmp_path):
    """A measured (non-interpret) sweep writes its choice to the on-disk
    JSON keyed (backend, shape class); a fresh process-alike (cleared
    in-memory cache) reloads it WITHOUT re-measuring."""
    import json
    kp, calls = _fake_measured_autotune(monkeypatch, tmp_path)
    sample = (jnp.zeros((2, 64), jnp.float32),
              jnp.zeros((2, 64, 3), jnp.float32),
              jnp.zeros((2, 40, 3), jnp.float32))
    choice = kp.best_block_t(64, 2, 40, interpret=False, sample=sample)
    assert choice % 128 == 0 and calls
    data = json.loads((tmp_path / "cache.json").read_text())
    backend = jax.default_backend()
    assert data["version"] == kp._SCHEMA_VERSION      # versioned schema
    assert data["entries"][backend]["64,2,40"] == choice

    # "new process": clear the in-memory cache, keep the disk file
    monkeypatch.setattr(kp, "_BLOCK_CACHE", {})
    monkeypatch.setattr(kp, "_PERSIST_LOADED", False)
    calls.clear()
    assert kp.best_block_t(64, 2, 40, interpret=False, sample=sample) == choice
    assert calls == []                  # served from disk, no warmup sweep


def test_autotune_legacy_unversioned_cache_migrates(monkeypatch, tmp_path):
    """The original unversioned on-disk format ({backend: {key: block}})
    loads silently (v1 migration), and the first save rewrites the file in
    the versioned schema without dropping migrated entries.  A FUTURE
    version this build does not understand is ignored, never misread."""
    import json
    kp, calls = _fake_measured_autotune(monkeypatch, tmp_path)
    backend = jax.default_backend()
    (tmp_path / "cache.json").write_text(
        json.dumps({backend: {"64,2,40": 256}}))    # legacy v1 layout
    assert kp.best_block_t(64, 2, 40, interpret=False) == 256
    assert calls == []                  # migrated entry served, no sweep

    # a save migrates the whole file to the versioned layout
    sample = (jnp.zeros((2, 128), jnp.float32),
              jnp.zeros((2, 128, 3), jnp.float32),
              jnp.zeros((2, 200, 3), jnp.float32))
    kp.best_block_t(128, 2, 200, interpret=False, sample=sample)
    data = json.loads((tmp_path / "cache.json").read_text())
    assert data["version"] == kp._SCHEMA_VERSION
    assert data["entries"][backend]["64,2,40"] == 256     # survived migration
    assert "128,2,200" in data["entries"][backend]

    # future-versioned file: ignored wholesale (sweep re-runs, no crash)
    (tmp_path / "cache.json").write_text(
        json.dumps({"version": 99, "entries": {backend: {"64,2,40": 512}}}))
    monkeypatch.setattr(kp, "_BLOCK_CACHE", {})
    monkeypatch.setattr(kp, "_PERSIST_LOADED", False)
    calls.clear()
    kp.best_block_t(64, 2, 40, interpret=False,
                    sample=(jnp.zeros((2, 64), jnp.float32),
                            jnp.zeros((2, 64, 3), jnp.float32),
                            jnp.zeros((2, 40, 3), jnp.float32)))
    assert calls                        # not served from the future file


def test_stream_autotune_heuristic_and_persistence(monkeypatch, tmp_path):
    """best_stream_params: interpret mode caches a VMEM-budget heuristic
    (never touching disk); a measured sweep persists its [block_t,
    n_buffers] under the "stream:" key prefix alongside the gathered
    entries, and a fresh process-alike reloads it without re-measuring."""
    import json
    kp, _ = _fake_measured_autotune(monkeypatch, tmp_path)
    bt, nb = kp.best_stream_params(256, 40, 64, interpret=True)
    assert bt % 128 == 0 and nb in kp.STREAM_BUFFER_CANDIDATES
    assert not (tmp_path / "cache.json").exists()

    measured = []

    def fake_measure(block_t, n_buffers):
        measured.append((block_t, n_buffers))
        return 0.1 if (block_t, n_buffers) == (128, 3) else 1.0

    monkeypatch.setattr(kp, "_STREAM_CACHE", {})
    choice = kp.best_stream_params(256, 40, 512, interpret=False,
                                   measure=fake_measure)
    assert choice == (128, 3) and measured
    data = json.loads((tmp_path / "cache.json").read_text())
    entry = data["entries"][jax.default_backend()]["stream:256,40,512"]
    assert entry == [128, 3]

    monkeypatch.setattr(kp, "_STREAM_CACHE", {})
    monkeypatch.setattr(kp, "_PERSIST_LOADED", False)
    measured.clear()
    assert kp.best_stream_params(256, 40, 512, interpret=False,
                                 measure=fake_measure) == (128, 3)
    assert measured == []               # served from disk, no sweep


def test_autotune_persistence_env_opt_out(monkeypatch, tmp_path):
    kp, calls = _fake_measured_autotune(monkeypatch, tmp_path)
    monkeypatch.setenv("REPRO_P2P_CACHE", "0")
    sample = (jnp.zeros((1, 64), jnp.float32),
              jnp.zeros((1, 64, 3), jnp.float32),
              jnp.zeros((1, 40, 3), jnp.float32))
    kp.best_block_t(64, 1, 40, interpret=False, sample=sample)
    assert calls                        # measured in-process...
    assert not (tmp_path / "cache.json").exists()   # ...but never persisted


def test_autotune_interpret_mode_never_touches_disk(monkeypatch, tmp_path):
    from repro.kernels import p2p as kp
    monkeypatch.setenv("REPRO_P2P_CACHE_PATH", str(tmp_path / "cache.json"))
    monkeypatch.setattr(kp, "_BLOCK_CACHE", {})
    monkeypatch.setattr(kp, "_PERSIST_LOADED", False)
    assert kp.best_block_t(64, 3, 32, interpret=True) in kp.BLOCK_CANDIDATES
    assert not (tmp_path / "cache.json").exists()
    assert kp._PERSIST_LOADED is False  # load path skipped entirely


def test_autotune_unwritable_cache_degrades_warn_once(monkeypatch, tmp_path):
    """An unusable cache location (here: a path UNDER a regular file, the
    read-only-container shape chmod can't fake for root) must warn exactly
    once, flip to in-memory-only operation, keep autotuning correctly and
    never warn or touch disk again — the disk cache is an optimization,
    not a liveness dependency."""
    import warnings
    kp, calls = _fake_measured_autotune(monkeypatch, tmp_path)
    blocker = tmp_path / "blocker"
    blocker.write_text("i am a file, not a cache directory")
    monkeypatch.setenv("REPRO_P2P_CACHE_PATH", str(blocker / "cache.json"))

    def sweep(S):
        sample = (jnp.zeros((2, S), jnp.float32),
                  jnp.zeros((2, S, 3), jnp.float32),
                  jnp.zeros((2, 40, 3), jnp.float32))
        return kp.best_block_t(S, 2, 40, interpret=False, sample=sample)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        choice = sweep(64)
    assert choice in kp.BLOCK_CANDIDATES          # degraded, still correct
    assert kp._PERSIST_BROKEN is True
    runtime_ws = [x for x in w if issubclass(x.category, RuntimeWarning)]
    assert len(runtime_ws) == 1
    assert "p2p autotune cache disabled" in str(runtime_ws[0].message)
    assert "REPRO_P2P_CACHE" in str(runtime_ws[0].message)  # remediation hint

    # a second shape class: measured in-memory, NO second warning, and the
    # in-memory cache still serves repeats without re-measuring
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        c2 = sweep(128)
        calls.clear()
        assert sweep(128) == c2                   # in-memory hit
        assert calls == []
    assert not [x for x in w2 if issubclass(x.category, RuntimeWarning)]
    assert not blocker.is_dir()                   # disk was never touched
