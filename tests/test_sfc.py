"""SFC key properties — including the Hilbert adjacency invariant, checked
with hypothesis (consecutive Hilbert keys decode to grid-adjacent cells)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.partition import sfc


@given(st.integers(2, 6), st.data())
@settings(max_examples=25, deadline=None)
def test_morton_roundtrip(depth, data):
    n = data.draw(st.integers(1, 64))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    g = rng.integers(0, 1 << depth, (n, 3)).astype(np.uint64)
    k = sfc.morton_encode(g, depth)
    np.testing.assert_array_equal(sfc.morton_decode(k, depth), g)


@given(st.integers(2, 6), st.data())
@settings(max_examples=25, deadline=None)
def test_hilbert_roundtrip(depth, data):
    n = data.draw(st.integers(1, 64))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    g = rng.integers(0, 1 << depth, (n, 3)).astype(np.uint64)
    k = sfc.hilbert_encode(g, depth)
    np.testing.assert_array_equal(sfc.hilbert_decode(k, depth), g)


def test_hilbert_is_bijection_small():
    depth = 3
    total = 1 << (3 * depth)
    keys = np.arange(total, dtype=np.uint64)
    g = sfc.hilbert_decode(keys, depth)
    back = sfc.hilbert_encode(g, depth)
    np.testing.assert_array_equal(back, keys)


def test_hilbert_adjacency_property():
    """THE Hilbert property: consecutive keys are adjacent grid cells
    (L1 distance exactly 1).  Morton does NOT satisfy this."""
    depth = 4
    total = 1 << (3 * depth)
    keys = np.arange(total, dtype=np.uint64)
    g = sfc.hilbert_decode(keys, depth).astype(np.int64)
    step = np.abs(np.diff(g, axis=0)).sum(axis=1)
    assert (step == 1).all()
    gm = sfc.morton_decode(keys, depth).astype(np.int64)
    stepm = np.abs(np.diff(gm, axis=0)).sum(axis=1)
    assert (stepm > 1).any()


def test_morton_key_order_matches_octants():
    depth = 2
    g = np.array([[0, 0, 0], [3, 3, 3], [0, 0, 1], [2, 0, 0]], dtype=np.uint64)
    k = sfc.morton_encode(g, depth)
    assert k[0] < k[2] < k[3] < k[1]
