"""Hypothesis property sweep for the device dual traversal: for ANY ragged
partitioning (sizes, ncrit, distributions, empty partitions) the device
while_loop program must emit the host reference's pair lists exactly.

Robustness certificate: the device scores the MAC in f32 while the host
scores in f64, so a razor-thin margin (or an exact radius tie in the
split-larger rule) can legitimately flip a decision between backends.  A
case counts as *robust* when jittering theta and the radii by ~1e-5 — two
orders of magnitude above f32 rounding — leaves the host pair sets
unchanged; only robust cases are asserted (non-robust draws are discarded
with `assume`, mirroring how the fixed golden seeds were chosen)."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import assume, given, settings, strategies as st

from repro.core.distributions import make_distribution
from repro.core.engine.traversal import device_dual_traversal
from repro.core.traversal import dual_traversal
from repro.core.tree import build_tree


def _pairsets(res):
    return tuple(frozenset(map(tuple, np.asarray(p).tolist())) for p in res)


def _jittered(tree, rng, scale=1e-5):
    r = np.asarray(tree.radius)
    jit = r * (1.0 + rng.uniform(-scale, scale, len(r)))
    return dataclasses.replace(tree, radius=jit)


def _robust(tree, theta, rng):
    """True iff the host decisions survive multiplicative theta/radius jitter
    two orders of magnitude above f32 epsilon."""
    base = _pairsets(dual_traversal(tree, tree, theta, with_m2p=True))
    for _ in range(2):
        jt = _jittered(tree, rng)
        for th in (theta * (1 - 1e-5), theta * (1 + 1e-5)):
            if _pairsets(dual_traversal(jt, jt, th, with_m2p=True)) != base:
                return False
    return True


@given(st.integers(0, 10_000), st.sampled_from(["sphere", "plummer", "cube"]),
       st.integers(16, 64))
@settings(max_examples=6, deadline=None)
def test_device_traversal_matches_host(seed, dist, ncrit):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(150, 500))
    x = make_distribution(dist, n, seed=seed)
    q = rng.uniform(-1, 1, n)
    t = build_tree(x, q, ncrit=ncrit)
    assume(_robust(t, 0.5, rng))
    m2l_h, p2p_h = dual_traversal(t, t, 0.5)
    m2l_d, p2p_d, m2p_d, _ = device_dual_traversal(t, t, 0.5)
    np.testing.assert_array_equal(m2l_d, m2l_h)
    np.testing.assert_array_equal(p2p_d, p2p_h)
    assert len(m2p_d) == 0


@given(st.integers(0, 5_000))
@settings(max_examples=4, deadline=None)
def test_device_geometry_empty_sentinel_partitions(seed):
    """Geometry-level sweep mirroring test_engine_property: duplicated
    coordinate clusters leave empty (inf/-inf sentinel) partitions, which
    the device backend must plan identically to the host backend."""
    from repro.core.api import PartitionSpec, plan_geometry
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (4, 3))
    x = np.repeat(pts, 40, axis=0)      # exact duplicates => empty partitions
    q = rng.uniform(-1, 1, len(x))
    spec = PartitionSpec(nparts=8, method="morton", ncrit=64)
    geo_h = plan_geometry(x, q, spec)
    live = [t for t in geo_h.trees if t is not None]
    assume(all(_robust(t, spec.theta, rng) for t in live))
    geo_d = plan_geometry(x, q, spec, traversal_backend="device")
    np.testing.assert_array_equal(geo_d.bytes_matrix, geo_h.bytes_matrix)
    for rh, rd in zip(geo_h.receivers, geo_d.receivers):
        assert (rh is None) == (rd is None)
        if rh is None:
            continue
        np.testing.assert_array_equal(rd.local.m2l_a, rh.local.m2l_a)
        np.testing.assert_array_equal(rd.local.m2l_b, rh.local.m2l_b)
        for a, b in zip(rh.remote, rd.remote):
            np.testing.assert_array_equal(b.inter.m2l_a, a.inter.m2l_a)
            np.testing.assert_array_equal(b.inter.m2l_b, a.inter.m2l_b)
