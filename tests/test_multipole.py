"""Correctness of the Cartesian Taylor operators against direct summation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multipole as mp


def _clusters(seed=0, n=32, sep=6.0):
    rng = np.random.default_rng(seed)
    src = rng.uniform(-0.5, 0.5, (n, 3))
    tgt = rng.uniform(-0.5, 0.5, (n, 3)) + np.array([sep, 0.0, 0.0])
    q = rng.uniform(-1, 1, n)
    return jnp.asarray(src), jnp.asarray(q), jnp.asarray(tgt)


def _direct(q, src, tgt):
    d = np.asarray(tgt)[:, None, :] - np.asarray(src)[None, :, :]
    r = np.sqrt((d ** 2).sum(-1))
    return (np.asarray(q)[None, :] / r).sum(-1)


def test_num_coeffs():
    assert mp.num_coeffs(4) == 20
    assert len(mp.multi_indices(3)) == 20
    assert len(mp.multi_indices(6)) == 84


def test_derivs_match_fd():
    ops = mp.MultipoleOperators(4)
    d = jnp.array([1.3, -0.7, 2.1])
    D = ops.derivs(d)
    # order-0 = G, order-1 = grad G
    g = 1.0 / np.linalg.norm(d)
    np.testing.assert_allclose(D[0], g, rtol=1e-6)
    grad = -np.asarray(d) / np.linalg.norm(d) ** 3
    # E order-1 rows are (1,0,0), (0,1,0), (0,0,1)
    np.testing.assert_allclose(D[1:4], grad, rtol=1e-5)


def test_p2m_m2p():
    src, q, tgt = _clusters(sep=8.0)
    M = mp.p2m(q, src, jnp.zeros(3))
    phi = mp.m2p(M, tgt, jnp.zeros(3))
    ref = _direct(q, src, tgt)
    err = np.linalg.norm(phi - ref) / np.linalg.norm(ref)
    assert err < 1e-3, err


def test_m2m_preserves_field():
    src, q, tgt = _clusters(sep=10.0)
    c_child = jnp.asarray(np.mean(np.asarray(src), axis=0))
    c_parent = c_child + jnp.array([0.3, -0.2, 0.1])
    M_child = mp.p2m(q, src, c_child)
    M_parent = mp.m2m(M_child, c_child - c_parent)
    M_direct = mp.p2m(q, src, c_parent)
    phi_t = mp.m2p(M_parent, tgt, c_parent)
    phi_d = mp.m2p(M_direct, tgt, c_parent)
    np.testing.assert_allclose(phi_t, phi_d, rtol=1e-5, atol=1e-7)


def test_m2l_l2l_l2p_chain():
    src, q, tgt = _clusters(sep=6.0, n=48)
    c_src = jnp.asarray(np.mean(np.asarray(src), axis=0))
    c_tgt = jnp.asarray(np.mean(np.asarray(tgt), axis=0))
    M = mp.p2m(q, src, c_src)
    L = mp.m2l(M, c_tgt - c_src)
    phi = mp.l2p(L, tgt, c_tgt)
    ref = _direct(q, src, tgt)
    err = np.linalg.norm(np.asarray(phi) - ref) / np.linalg.norm(ref)
    assert err < 2e-3, err
    # chain through an intermediate L2L hop
    c_mid = c_tgt + jnp.array([0.2, 0.1, -0.15])
    L_mid = mp.m2l(M, c_mid - c_src)
    L2 = mp.l2l(L_mid, c_tgt - c_mid)
    phi2 = mp.l2p(L2, tgt, c_tgt)
    err2 = np.linalg.norm(np.asarray(phi2) - ref) / np.linalg.norm(ref)
    assert err2 < 4e-3, err2


def test_p2p_reference():
    src, q, tgt = _clusters(sep=1.0)
    phi = mp.p2p(q, src, tgt)
    ref = _direct(q, src, tgt)
    np.testing.assert_allclose(np.asarray(phi), ref, rtol=2e-4)


def test_p2p_self_interaction_zero():
    src, q, _ = _clusters()
    phi = mp.p2p(q, src, src)
    assert np.all(np.isfinite(np.asarray(phi)))


def test_convergence_with_order():
    """Higher expansion order => lower error (sanity on operator family)."""
    src, q, tgt = _clusters(sep=4.0)
    errs = []
    for p in (2, 3, 4):
        ops = mp.MultipoleOperators(p)
        c_src = jnp.asarray(np.mean(np.asarray(src), axis=0))
        c_tgt = jnp.asarray(np.mean(np.asarray(tgt), axis=0))
        M = ops.p2m(q, src, c_src)
        L = ops.m2l(M, c_tgt - c_src)
        phi = ops.l2p(L, tgt, c_tgt)
        ref = _direct(q, src, tgt)
        errs.append(np.linalg.norm(np.asarray(phi) - ref) / np.linalg.norm(ref))
    assert errs[2] < errs[1] < errs[0]
