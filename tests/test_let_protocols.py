"""LET extraction, protocol schedules, HSDX graph, and the distributed FMM
end-to-end: every protocol must deliver the identical LET, and the
distributed potential must match the O(N^2) direct oracle."""
import numpy as np
import pytest

from repro.core import protocols as proto
from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution
from repro.core.fmm import direct_potential, upward_pass
from repro.core.hsdx import adjacency_from_boxes, build_comm_tree, nb_bound, relay_routes
from repro.core.let import extract_let, graft
from repro.core.multipole import MultipoleOperators
from repro.core.partition.orb import orb_partition
from repro.core.tree import build_tree


def test_nb_bound_matches_paper():
    # paper: ceil((5^D - 3^D) / (3^D - 1)) -> for D=3: ceil(98/26) = 4
    assert nb_bound(3) == 4
    assert nb_bound(2) == 2


def test_adjacency_grid():
    # 2x2x1 grid of unit boxes: all share a face/edge -> fully adjacent
    boxes = np.array([
        [[0, 0, 0], [1, 1, 1]], [[1, 0, 0], [2, 1, 1]],
        [[0, 1, 0], [1, 2, 1]], [[1, 1, 0], [2, 2, 1]],
    ], dtype=float)
    adj = adjacency_from_boxes(boxes)
    assert all(len(a) == 3 for a in adj)


def test_comm_tree_balanced():
    # 1D chain 0-1-2-3-4: BFS tree from 2 has parents toward 2
    boxes = np.array([[[i, 0, 0], [i + 1, 1, 1]] for i in range(5)], dtype=float)
    adj = adjacency_from_boxes(boxes)
    parent = build_comm_tree(adj, 2)
    assert parent[2] == -1 and parent[1] == 2 and parent[3] == 2
    assert parent[0] == 1 and parent[4] == 3
    routes = relay_routes(adj)
    assert routes[(0, 4)] == [0, 1, 2, 3, 4]


@pytest.mark.parametrize("protocol", proto.PROTOCOLS)
def test_protocol_delivers_identical_let(protocol):
    rng = np.random.default_rng(0)
    P = 8
    B = rng.integers(0, 5000, (P, P))
    np.fill_diagonal(B, 0)
    boxes = np.array([[[i % 2, (i // 2) % 2, i // 4], [i % 2 + 1, (i // 2) % 2 + 1, i // 4 + 1]]
                      for i in range(P)], dtype=float)
    sched = proto.make_schedule(protocol, B, boxes=boxes)
    delivered = proto.simulate_delivery(sched)
    expect = {(i, j): int(B[i, j]) for i in range(P) for j in range(P) if i != j and B[i, j]}
    assert delivered == expect


def test_protocol_complexities():
    """Table 2-style structure: stage counts per protocol."""
    P = 16
    B = np.ones((P, P), dtype=np.int64) * 1000
    np.fill_diagonal(B, 0)
    boxes = np.array([[[i, 0, 0], [i + 1, 1, 1]] for i in range(P)], dtype=float)
    s_a2a = proto.make_schedule("alltoallv", B)
    s_pw = proto.make_schedule("pairwise", B)
    s_hx = proto.make_schedule("hsdx", B, boxes=boxes)
    assert s_a2a.n_stages == 1
    assert proto.schedule_stats(s_a2a)["n_msgs"] == P * (P - 1)
    assert s_pw.n_stages == 4  # log2(16)
    # chain adjacency -> diameter P-1 stages, but only neighbor messages
    st = proto.schedule_stats(s_hx)
    assert st["max_msgs_per_dst_stage"] <= 2  # chain: at most 2 neighbors
    # pairwise relays inflate wire bytes; alltoallv does not
    assert proto.schedule_stats(s_pw)["relay_factor"] > 1.0
    assert proto.schedule_stats(s_a2a)["relay_factor"] == 1.0


def test_loggp_granularity_cliff():
    """Fig 6: crossing the eager limit adds the rendezvous penalty."""
    B = np.zeros((2, 2), dtype=np.int64)
    B[0, 1] = 64 * 1024
    s = proto.make_schedule("alltoallv", B)
    t_small_grain = proto.loggp_time(s, grain_bytes=4096)   # stays eager
    t_bulk = proto.loggp_time(s)                            # one rendezvous msg
    prm = proto.LogGPParams()
    # bulk pays rendezvous once; small grain pays many overheads
    assert t_bulk > prm.rendezvous_penalty
    assert t_small_grain > 16 * prm.o                       # 16 chunks


def test_let_extraction_conservative():
    n = 3000
    x = make_distribution("sphere", n, seed=2)
    q = np.random.default_rng(3).uniform(-1, 1, n)
    part, boxes = orb_partition(x, 4)
    idx0 = np.nonzero(part == 0)[0]
    t0 = build_tree(x[idx0], q[idx0], ncrit=48)
    ops = MultipoleOperators(4)
    M0 = np.asarray(upward_pass(t0, ops))
    let = extract_let(t0, M0, boxes[1, 0], boxes[1, 1], theta=0.5)
    assert let.n_cells > 0 and let.n_cells <= t0.n_cells
    g = graft(let)
    # grafted tree structurally valid
    assert g.n_cells == let.n_cells
    for c in range(g.n_cells):
        if g.n_child[c]:
            assert g.child_start[c] > c
    # truncated cells carry no bodies and no children
    trunc = np.nonzero(let.truncated)[0]
    assert np.all(let.n_child[trunc] == 0) and np.all(let.n_body[trunc] == 0)


@pytest.mark.parametrize("method,protocol", [
    ("orb", "hsdx"), ("orb", "alltoallv"), ("orb", "pairwise"),
    ("hilbert", "alltoallv"), ("morton", "hsdx"), ("orb", "nbx"),
])
def test_distributed_fmm_matches_direct(method, protocol):
    n = 2000
    x = make_distribution("sphere", n, seed=5)
    q = np.random.default_rng(6).uniform(-1, 1, n)
    res = run_distributed_fmm(x, q, nparts=5 if method == "orb" else 4,
                              method=method, protocol=protocol,
                              theta=0.5, ncrit=48)
    ref = direct_potential(x, q)
    err = np.linalg.norm(res.phi - ref) / np.linalg.norm(ref)
    assert err < 3e-3, f"{method}/{protocol}: {err}"


def test_hsdx_reduces_contention_vs_alltoall():
    """The paper's core claim, structurally: HSDX bounds per-stage fan-in to
    the neighbor count while alltoallv has P-1 fan-in."""
    n = 4000
    x = make_distribution("sphere", n, seed=9)
    q = np.ones(n)
    r_hx = run_distributed_fmm(x, q, nparts=8, method="orb", protocol="hsdx",
                               check_delivery=True)
    r_a2a = run_distributed_fmm(x, q, nparts=8, method="orb", protocol="alltoallv")
    assert r_hx.schedule_stats["max_msgs_per_dst_stage"] <= r_hx.adjacency_degree + 1
    assert r_a2a.schedule_stats["max_msgs_per_dst_stage"] == 7
    np.testing.assert_allclose(r_hx.phi, r_a2a.phi, rtol=1e-10)
