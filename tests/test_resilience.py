"""Chaos suite for the resilience tier (ISSUE 10).

Each registered fault site is fired exactly once against a session whose
knobs make that seam load-bearing, and the test asserts the PRECISE
consequence: either phi still lands within the engine-parity tolerance via
a counted ladder fallback, or a typed `ResilienceError` naming the site
surfaces.  Plus: retry/backoff with an injectable clock, cache corruption
quarantine, input validation, the report surface, and the two performance
pins (disabled-mode fire() allocates nothing; resilience armed with no
faults leaves the warm fused one-launch contract intact).
"""
import json
import os
import subprocess
import sys
import tracemalloc
import warnings

import numpy as np
import pytest

from repro.core.api import FMMSession, PartitionSpec, plan_geometry
from repro.resilience import fallback as res_fb
from repro.resilience import faults as res_faults
from repro.resilience import (ExchangeVerificationError, InjectedFault,
                              InjectedResourceExhausted, ResilienceError,
                              RetryPolicy, call_with_retry, inject_faults)

RTOL, ATOL = 1e-6, 2e-5


def _problem(n=192, nparts=4, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, size=(n, 3))
    q = rng.uniform(0.1, 1.0, size=n)
    return x, q, PartitionSpec(nparts=nparts, ncrit=48)


@pytest.fixture(scope="module")
def reference_phi():
    x, q, spec = _problem()
    sess = FMMSession.from_points(x, q, spec, engine=False)
    return np.asarray(sess.evaluate(), np.float64)


# --------------------------------------------------------------- matrix ---
# site -> session knobs that make the seam load-bearing on CPU.  Each case
# fires the site once; the resilient session must land one rung lower and
# still produce a parity-tolerance phi with exactly one counted fallback.
MATRIX = {
    "memo.upload": dict(engine=True, fused=False, use_kernels=False,
                        p2p_stream=False),
    "exe_cache.compile": dict(engine=True, fused=True, use_kernels=False,
                              p2p_stream=False),
    "fused.launch": dict(engine=True, fused=True, use_kernels=False,
                         p2p_stream=False),
    "p2p.stream.tables": dict(engine=True, fused=False, use_kernels=False,
                              p2p_stream=True),
    "kernels.p2p.launch": dict(engine=True, fused=False, use_kernels=True,
                               p2p_stream=False),
}


@pytest.mark.parametrize("site", sorted(MATRIX))
def test_chaos_matrix_fallback_preserves_phi(site, reference_phi):
    x, q, spec = _problem()
    sess = FMMSession.from_points(x, q, spec, resilience=True,
                                  **MATRIX[site])
    rung_before = sess._current_rung()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with inject_faults(site):
            phi = sess.evaluate()
    st = sess.resilience
    assert st.degraded
    assert len(st.fallbacks) == 1
    assert st.fallbacks[0]["site"] == site
    assert st.fallbacks[0]["from"] == rung_before
    assert res_faults.fired_counts() == {site: 1}
    assert res_fb.ledger_counts()["fallbacks"] == {site: 1}
    np.testing.assert_allclose(phi, reference_phi, rtol=RTOL, atol=ATOL)


def test_chaos_dist_build_program_falls_back_to_engine(reference_phi):
    from repro.launch.mesh import host_device_mesh
    x, q, spec = _problem()
    mesh = host_device_mesh(1)
    sess = FMMSession.from_points(x, q, spec, mesh=mesh, resilience=True,
                                  engine=True, fused=False,
                                  use_kernels=False, p2p_stream=False)
    assert sess._current_rung() == "dist"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with inject_faults("dist.build_program"):
            phi = sess.evaluate()
    st = sess.resilience
    assert st.degraded and st.fallbacks[0]["from"] == "dist"
    assert sess.mesh is None and sess._dist is None
    assert st.rung != "dist"
    np.testing.assert_allclose(phi, reference_phi, rtol=RTOL, atol=ATOL)


def test_ladder_walks_multiple_rungs(reference_phi):
    # streaming -> (kernel launch fault) -> gathered -> (again) -> xla_slab
    x, q, spec = _problem()
    sess = FMMSession.from_points(x, q, spec, resilience=True, engine=True,
                                  fused=False, use_kernels=True,
                                  p2p_stream=True)
    assert sess._current_rung() == "streaming"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with inject_faults({"kernels.p2p.launch": {"count": 2}}):
            phi = sess.evaluate()
    transitions = [(f["from"], f["to"]) for f in sess.resilience.fallbacks]
    assert transitions == [("streaming", "gathered"), ("gathered", "xla_slab")]
    assert sess.resilience.rung == "xla_slab"
    np.testing.assert_allclose(phi, reference_phi, rtol=RTOL, atol=ATOL)


def test_ladder_exhaustion_raises_typed_error():
    x, q, spec = _problem(n=96, nparts=2)
    # reference rung still uploads through the memo: an unlimited fault
    # there leaves nowhere to go
    sess = FMMSession.from_points(x, q, spec, resilience=True, engine=False)
    assert sess._current_rung() == "reference"
    with pytest.raises(ResilienceError) as ei:
        with inject_faults({"memo.upload": {"count": None}}):
            sess.evaluate()
    assert ei.value.site == "memo.upload"
    assert res_fb.ledger_counts()["typed_errors"] == {"memo.upload": 1}


def test_without_resilience_faults_propagate():
    x, q, spec = _problem(n=96, nparts=2)
    sess = FMMSession.from_points(x, q, spec, engine=False)  # default: off
    with pytest.raises(InjectedFault):
        with inject_faults("memo.upload"):
            sess.evaluate()
    assert not sess.resilience.enabled


def test_accounting_identity_across_matrix():
    # every fired fault is a counted fallback or a typed error — the
    # check_counters gate, asserted in-process across a mixed run
    x, q, spec = _problem(n=96, nparts=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        s1 = FMMSession.from_points(x, q, spec, resilience=True,
                                    engine=True, fused=True,
                                    use_kernels=False, p2p_stream=False)
        with inject_faults("fused.launch"):
            s1.evaluate()
        s2 = FMMSession.from_points(x, q, spec, resilience=True,
                                    engine=False)
        with pytest.raises(ResilienceError):
            with inject_faults({"memo.upload": {"count": None}}):
                s2.evaluate()
    fired = res_faults.fired_total()
    assert fired >= 2
    assert fired == res_fb.fallback_total() + res_fb.typed_error_total()


# ---------------------------------------------------------------- retry ---
def test_transient_faults_retry_with_deterministic_backoff(reference_phi):
    delays = []
    x, q, spec = _problem()
    sess = FMMSession.from_points(x, q, spec, resilience=True, engine=True,
                                  fused=False, use_kernels=False,
                                  p2p_stream=False)
    sess.resilience.retry = RetryPolicy(max_retries=2, base_delay=0.05,
                                        max_delay=1.0, sleep=delays.append)
    with inject_faults({"memo.upload": {"count": 2, "transient": True}}):
        phi = sess.evaluate()
    assert delays == [0.05, 0.1]            # base * 2**k, injectable clock
    assert sess.resilience.retries == 2
    assert not sess.resilience.degraded     # retried in place, no downgrade
    assert res_fb.retry_total() == 2
    np.testing.assert_allclose(phi, reference_phi, rtol=RTOL, atol=ATOL)


def test_call_with_retry_gives_up_after_budget():
    calls = []

    def always_fails():
        calls.append(1)
        raise InjectedFault("exe_cache.compile", transient=True)

    with pytest.raises(InjectedFault):
        call_with_retry(always_fails, site="exe_cache.compile",
                        policy=RetryPolicy(max_retries=2,
                                           sleep=lambda s: None))
    assert len(calls) == 3                  # initial + 2 retries


def test_retry_delay_caps_at_max():
    p = RetryPolicy(max_retries=8, base_delay=0.05, max_delay=0.15)
    assert [p.delay(k) for k in range(4)] == [0.05, 0.1, 0.15, 0.15]


def test_non_transient_never_retries():
    calls = []

    def fails():
        calls.append(1)
        raise InjectedFault("fused.launch")     # transient=False

    with pytest.raises(InjectedFault):
        call_with_retry(fails, site="fused.launch",
                        policy=RetryPolicy(sleep=lambda s: None))
    assert len(calls) == 1


# ------------------------------------------------------- cache hardening --
@pytest.fixture
def p2p_cache_sandbox(monkeypatch, tmp_path):
    from repro.kernels import p2p as kp
    path = tmp_path / "p2p_cache.json"
    monkeypatch.setenv("REPRO_P2P_CACHE_PATH", str(path))
    monkeypatch.setenv("REPRO_P2P_CACHE", "1")
    monkeypatch.setattr(kp, "_BLOCK_CACHE", {})
    monkeypatch.setattr(kp, "_STREAM_CACHE", {})
    monkeypatch.setattr(kp, "_PERSIST_LOADED", False)
    monkeypatch.setattr(kp, "_PERSIST_BROKEN", False)
    monkeypatch.setattr(kp, "_QUARANTINED", False)
    return kp, path


def test_corrupt_cache_quarantined_warn_once(p2p_cache_sandbox):
    kp, path = p2p_cache_sandbox
    path.write_text('{"version": 2, "entries": {"cpu": {TRUNCATED')
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        kp._load_persisted("cpu")           # must NOT raise JSONDecodeError
        kp._PERSIST_LOADED = False
        kp._load_persisted("cpu")           # second sight: silent
    quarantine_warns = [m for m in w if "corrupt" in str(m.message)]
    assert len(quarantine_warns) == 1
    assert os.path.exists(str(path) + ".corrupt")
    assert not kp._PERSIST_BROKEN           # location usable: persistence ON
    # the next save rebuilds a clean file at the same path
    kp._save_persisted("cpu", "64,4,128", 128)
    data = json.loads(path.read_text())
    assert data["entries"]["cpu"]["64,4,128"] == 128


def test_corrupt_cache_on_save_merge_quarantines(p2p_cache_sandbox):
    kp, path = p2p_cache_sandbox
    path.write_text("not json at all")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        kp._save_persisted("cpu", "64,4,128", 256)
    assert any("corrupt" in str(m.message) for m in w)
    assert json.loads(path.read_text())["entries"]["cpu"]["64,4,128"] == 256


@pytest.mark.parametrize("site,action", [("p2p.cache.read", "read"),
                                         ("p2p.cache.write", "write")])
def test_injected_cache_io_fault_absorbed_locally(p2p_cache_sandbox, site,
                                                 action):
    kp, path = p2p_cache_sandbox
    path.write_text('{"version": 2, "entries": {}}')
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with inject_faults(site):
            if action == "read":
                kp._load_persisted("cpu")
            else:
                kp._save_persisted("cpu", "64,4,128", 128)
    assert kp._PERSIST_BROKEN               # degraded to in-memory-only
    assert len([m for m in w if issubclass(m.category, RuntimeWarning)]) == 1
    # absorbed locally (ledgered), never escalated to a typed error
    assert res_fb.ledger_counts()["fallbacks"] == {site: 1}
    assert res_fb.typed_error_total() == 0
    assert res_faults.fired_counts() == {site: 1}


# ----------------------------------------------------------- validation ---
def test_plan_geometry_rejects_bad_inputs():
    x, q, spec = _problem(n=32, nparts=2)
    with pytest.raises(ValueError, match="x: expected positions"):
        plan_geometry(np.zeros((8, 2)), np.ones(8), spec)
    with pytest.raises(ValueError, match="x: at least one body"):
        plan_geometry(np.zeros((0, 3)), np.zeros(0), spec)
    with pytest.raises(ValueError, match="q: expected charges"):
        plan_geometry(x, q[:-1], spec)
    bad = x.copy()
    bad[3, 1] = np.nan
    with pytest.raises(ValueError, match="x: positions contain non-finite"):
        plan_geometry(bad, q, spec)
    bad_q = q.copy()
    bad_q[0] = np.inf
    with pytest.raises(ValueError, match="q: charges contain non-finite"):
        plan_geometry(x, bad_q, spec)
    with pytest.raises(ValueError, match="theta: MAC opening angle"):
        plan_geometry(x, q, PartitionSpec(nparts=2, theta=-0.5))
    with pytest.raises(ValueError, match="theta"):
        plan_geometry(x, q, PartitionSpec(nparts=2, theta=float("nan")))


def test_session_rejects_non_plan_geometry():
    with pytest.raises(ValueError, match="geometry: expected a GeometryPlan"):
        FMMSession(np.zeros((4, 3)))


def test_step_rejects_non_finite_updates():
    x, q, spec = _problem(n=64, nparts=2)
    sess = FMMSession.from_points(x, q, spec, engine=False)
    bad = x.copy()
    bad[5, 0] = np.nan
    with pytest.raises(ValueError, match="new_x: positions contain"):
        sess.step(bad)
    bad_q = q.copy()
    bad_q[1] = -np.inf
    with pytest.raises(ValueError, match="new_q: charges contain"):
        sess.step(x, bad_q)


def test_empty_partition_sentinel_still_works():
    # n < nparts leaves empty partitions: the inf/-inf box sentinel path —
    # deliberately NOT rejected by validation (clustered problems do this)
    rng = np.random.default_rng(3)
    x = rng.uniform(size=(5, 3))
    q = rng.uniform(0.1, 1.0, size=5)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=8, ncrit=16),
                                  engine=False)
    phi = sess.evaluate()
    ref = FMMSession.from_points(x, q, PartitionSpec(nparts=1, ncrit=16),
                                 engine=False).evaluate()
    assert np.isfinite(phi).all()
    np.testing.assert_allclose(phi, ref, rtol=RTOL, atol=ATOL)


# ------------------------------------------------------ health sentinels --
def test_health_check_catches_nan_phi(reference_phi, monkeypatch):
    from repro.core import engine as eng_mod
    x, q, spec = _problem()
    sess = FMMSession.from_points(x, q, spec, resilience=True,
                                  health_checks=True, engine=True,
                                  fused=False, use_kernels=False,
                                  p2p_stream=False)
    monkeypatch.setattr(
        eng_mod.DeviceEngine, "evaluate",
        lambda self: np.full(sess.geometry.n, np.nan), raising=True)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        phi = sess.evaluate()
    st = sess.resilience
    assert st.health["failures"] >= 1
    assert st.degraded and st.fallbacks[0]["site"] == "health.phi"
    assert st.rung == "reference"
    np.testing.assert_allclose(phi, reference_phi, rtol=RTOL, atol=ATOL)


def test_health_check_passes_clean_run():
    x, q, spec = _problem(n=96, nparts=2)
    sess = FMMSession.from_points(x, q, spec, resilience=True,
                                  health_checks=True, engine=False)
    sess.evaluate()
    st = sess.resilience
    assert st.health == {"checks": 1, "failures": 0}
    assert not st.degraded


def test_step_drift_failure_degrades_to_host_revalidation():
    x, q, spec = _problem()
    sess = FMMSession.from_points(x, q, spec, resilience=True, engine=True,
                                  fused=False, use_kernels=False,
                                  p2p_stream=False)
    phi0 = sess.evaluate()
    eng = sess.engine
    assert eng is not None

    def boom(new_x):
        raise RuntimeError("device revalidation died")

    eng.step_drift = boom
    eng.discard_pending = lambda: None
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        rep = sess.step(x + 1e-7)           # tiny within-slack drift
    assert sess.resilience.degraded
    fb = sess.resilience.fallbacks[0]
    assert (fb["from"], fb["to"]) == ("device_revalidation", "host")
    assert rep.version == sess.geometry.version
    phi1 = sess.evaluate()
    np.testing.assert_allclose(phi1, phi0, rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------- report -----
def test_report_resilience_block():
    x, q, spec = _problem(n=96, nparts=2)
    sess = FMMSession.from_points(x, q, spec, resilience=True, engine=False)
    sess.evaluate()
    blk = sess.report()["resilience"]
    assert blk["enabled"] is True
    assert blk["degraded"] is False
    assert blk["rung"] == "reference"
    assert blk["fallbacks"] == []
    assert set(blk) >= {"retries", "health", "audits", "exchange_verified",
                        "health_checks"}


# ------------------------------------------------------------ env / spec --
def test_parse_spec_grammar():
    spec = res_faults.parse_spec(
        "memo.upload, exe_cache.compile:3, fused.launch:*:0.5")
    assert spec["memo.upload"] == {}
    assert spec["exe_cache.compile"] == {"count": 3}
    assert spec["fused.launch"] == {"count": None, "prob": 0.5}
    with pytest.raises(ValueError, match="unknown fault site"):
        res_faults.parse_spec("no.such.site")
    with pytest.raises(ValueError, match="malformed"):
        res_faults.parse_spec("memo.upload:1:0.5:oops")


def test_env_arming(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "memo.upload:2")
    res_faults._arm_from_env()
    try:
        assert res_faults.active_plan() is not None
        with pytest.raises(InjectedFault):
            res_faults.fire("memo.upload")
    finally:
        res_faults.disarm()


def test_probabilistic_plan_is_seed_deterministic():
    def run(seed):
        fired = 0
        with inject_faults({"memo.upload": {"count": None, "prob": 0.5}},
                           seed=seed):
            for _ in range(64):
                try:
                    res_faults.fire("memo.upload")
                except InjectedFault:
                    fired += 1
        res_faults.reset_stats()
        return fired

    a, b = run(7), run(7)
    assert a == b and 0 < a < 64


def test_nested_arming_rejected():
    with inject_faults("memo.upload"):
        with pytest.raises(RuntimeError, match="already armed"):
            with inject_faults("fused.launch"):
                pass


def test_fused_launch_fault_is_resource_exhausted():
    with pytest.raises(InjectedResourceExhausted, match="RESOURCE_EXHAUSTED"):
        with inject_faults("fused.launch"):
            res_faults.fire("fused.launch")


def test_default_resilience_env(monkeypatch):
    x, q, spec = _problem(n=32, nparts=2)
    monkeypatch.setenv("REPRO_RESILIENCE", "1")
    assert FMMSession.from_points(x, q, spec).resilience.enabled
    monkeypatch.setenv("REPRO_RESILIENCE", "0")
    assert not FMMSession.from_points(x, q, spec).resilience.enabled


# ------------------------------------------------------ performance pins --
def test_disabled_fire_allocates_nothing():
    res_faults.disarm()
    for _ in range(100):                    # warm any lazy state
        res_faults.fire("memo.upload")
    tracemalloc.start()
    base = tracemalloc.take_snapshot()
    for _ in range(10_000):
        res_faults.fire("memo.upload")
    peak = tracemalloc.get_traced_memory()[1]
    tracemalloc.stop()
    del base
    assert peak < 8192, f"disabled fire() allocated {peak} bytes over 10k calls"


def test_warm_fused_one_launch_with_resilience_enabled():
    import jax
    from repro.analysis.hlo_walk import count_entry_launches
    from repro.core.engine.exe_cache import ExecutableCache
    x, q, spec = _problem()
    sess = FMMSession.from_points(x, q, spec, resilience=True, engine=True,
                                  fused=True, use_kernels=False,
                                  p2p_stream=False,
                                  exe_cache=ExecutableCache())
    sess.evaluate()
    sess.evaluate()
    entry, _tabs = sess.engine._entries[("evaluate",
                                         bool(jax.config.jax_enable_x64))]
    assert count_entry_launches(entry.hlo_text) == 1
    assert not sess.resilience.degraded


# ----------------------------------------------- multi-device subprocess --
_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import warnings
import numpy as np
from repro.core.api import FMMSession, PartitionSpec
from repro.launch.mesh import host_device_mesh
from repro.resilience import inject_faults

rng = np.random.default_rng(5)
x = rng.uniform(-1.0, 1.0, size=(256, 3))
q = rng.uniform(0.1, 1.0, size=256)
spec = PartitionSpec(nparts=8, ncrit=48)

ref = FMMSession.from_points(x, q, spec, engine=False).evaluate()

# 1. exchange verification on a real 4-rank mesh: every span word-exact
sess = FMMSession.from_points(x, q, spec, mesh=host_device_mesh(4),
                              engine=True, fused=False, use_kernels=False,
                              p2p_stream=False)
for protocol in ("bulk", "grain", "hsdx"):
    n_spans = sess.dist.verify_exchange(protocol)
    assert n_spans > 0, protocol

# 2. REPRO_VERIFY_EXCHANGE session hook: verified once per (proto, version)
os.environ["REPRO_VERIFY_EXCHANGE"] = "1"
sess.evaluate(); sess.evaluate()
assert sess.resilience.exchange_verified == 1
del os.environ["REPRO_VERIFY_EXCHANGE"]

# 3. corrupted wire -> ExchangeVerificationError naming the span
from repro.core.dist import engine as dist_eng
from repro.core.dist import programs as prog_mod
from repro.resilience import ExchangeVerificationError
real_apply = prog_mod.apply_exchange
def corrupt_apply(pool, program, rtabs, axis):
    out = real_apply(pool, program, rtabs, axis)
    return out.at[0].add(1.0)  # flip a word in every rank's pool
prog_mod.apply_exchange = corrupt_apply
sess2 = FMMSession.from_points(x, q, spec, mesh=host_device_mesh(4))
try:
    sess2.dist.verify_exchange("bulk")
    raise SystemExit("corrupted exchange was not detected")
except ExchangeVerificationError as e:
    assert e.site == "dist.exchange.verify"
prog_mod.apply_exchange = real_apply

# 4. dist failure -> single-device fallback, phi parity kept
sess3 = FMMSession.from_points(x, q, spec, mesh=host_device_mesh(4),
                               resilience=True, engine=True, fused=False,
                               use_kernels=False, p2p_stream=False)
assert sess3._current_rung() == "dist"
with warnings.catch_warnings():
    warnings.simplefilter("ignore", RuntimeWarning)
    with inject_faults("dist.build_program"):
        phi = sess3.evaluate()
st = sess3.resilience
assert st.degraded and st.fallbacks[0]["from"] == "dist"
assert st.fallbacks[0]["site"] == "dist.build_program"
assert sess3.mesh is None
np.testing.assert_allclose(phi, ref, rtol=1e-6, atol=2e-5)
print("DIST-RESILIENCE-OK")
"""


def test_dist_verify_and_fallback_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    env.pop("REPRO_FAULTS", None)
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "DIST-RESILIENCE-OK" in proc.stdout
