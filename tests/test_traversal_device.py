"""Device-resident dual traversal (repro.core.engine.traversal): the
`jax.lax.while_loop` frontier program + Pallas MAC kernel must reproduce the
host reference `core.traversal.dual_traversal` exactly — same pair SETS and,
because the device loop replicates the host's expansion ordering, the same
pair ORDER, which makes every downstream InteractionPlan (and therefore the
executed potential) byte-identical between backends.

The MAC decisions are scored in f32 on device vs f64 on the host, so exact
agreement is only guaranteed away from razor-thin margins; the fixed-seed
cases here are robust (verified by the theta/radius-jitter certificate used
in test_traversal_device_property.py)."""
import numpy as np
import pytest

import repro.core.engine.traversal as dtrav
from repro.core.api import PartitionSpec, execute_geometry, plan_geometry
from repro.core.distributions import make_distribution
from repro.core.engine.traversal import (device_dual_traversal,
                                         resolve_traversal_backend)
from repro.core.fmm import upward_pass
from repro.core.let import extract_let, graft
from repro.core.multipole import get_operators
from repro.core.traversal import dual_traversal
from repro.core.tree import build_tree, flat_cell_tables


def _problem(n=1500, seed=3, dist="sphere", ncrit=48):
    x = make_distribution(dist, n, seed=seed)
    q = np.random.default_rng(seed + 1).uniform(-1, 1, n)
    return x, q, build_tree(x, q, ncrit=ncrit)


# ------------------------------------------------------ golden: local pair --
@pytest.mark.parametrize("dist,ncrit", [("sphere", 48), ("plummer", 32),
                                        ("cube", 64)])
def test_device_traversal_matches_host_local(dist, ncrit):
    _, _, t = _problem(n=1200, dist=dist, ncrit=ncrit)
    m2l_h, p2p_h = dual_traversal(t, t, 0.5)
    m2l_d, p2p_d, m2p_d, margin = device_dual_traversal(t, t, 0.5)
    np.testing.assert_array_equal(m2l_d, m2l_h)   # order-identical, not just
    np.testing.assert_array_equal(p2p_d, p2p_h)   # set-identical
    assert len(m2p_d) == 0
    # the traversal's margin output IS the host slack quantity (f32 vs f64)
    a, b = m2l_h[:, 0], m2l_h[:, 1]
    d = np.linalg.norm(t.center[a] - t.center[b], axis=1)
    ref = float(np.min(0.5 * d - (t.radius[a] + t.radius[b])))
    np.testing.assert_allclose(margin, ref, rtol=1e-4, atol=1e-7)


def test_device_traversal_grafted_let_with_m2p():
    x, q, _ = _problem(n=1600, dist="sphere")
    idx = x[:, 0] < 0
    t_src = build_tree(x[idx], q[idx], ncrit=32)
    t_tgt = build_tree(x[~idx], q[~idx], ncrit=256)   # large leaves => M2P
    M = np.asarray(upward_pass(t_src, get_operators(4)))
    let = extract_let(t_src, M, x[~idx].min(0), x[~idx].max(0), 0.5)
    g = graft(let)
    host = dual_traversal(t_tgt, g, 0.5, with_m2p=True)
    dev = device_dual_traversal(t_tgt, g, 0.5, with_m2p=True)
    for h, d in zip(host, dev[:3]):
        np.testing.assert_array_equal(d, h)


def test_device_traversal_overflow_retry(monkeypatch):
    """Deliberately tiny initial capacities must transparently double (and
    remember the bump) rather than truncate or crash."""
    monkeypatch.setattr(dtrav, "_CAPS_CACHE", {})
    monkeypatch.setattr(dtrav, "traversal_caps",
                        lambda pad: (128, 128, 128, 128))
    _, _, t = _problem(n=800, ncrit=32)
    m2l_h, p2p_h = dual_traversal(t, t, 0.5)
    m2l_d, p2p_d, _, _ = device_dual_traversal(t, t, 0.5)
    np.testing.assert_array_equal(m2l_d, m2l_h)
    np.testing.assert_array_equal(p2p_d, p2p_h)
    assert dtrav._CAPS_CACHE          # the doubled caps were remembered


def test_flat_cell_tables_padding_is_inert():
    _, _, t = _problem(n=300, ncrit=32)
    tab = flat_cell_tables(t)
    C, Cpad = tab["n_cells"], len(tab["radius"])
    assert Cpad >= C and (Cpad & (Cpad - 1)) == 0
    assert tab["is_leaf"][C:].all() and not tab["n_child"][C:].any()
    assert not tab["truncated"].any()            # plain trees: no truncation
    with pytest.raises(ValueError):
        flat_cell_tables(t, pad_cells=C - 1)


def test_resolve_traversal_backend():
    assert resolve_traversal_backend("host") == "host"
    assert resolve_traversal_backend("device") == "device"
    assert resolve_traversal_backend(None) in ("host", "device")
    assert (resolve_traversal_backend("auto")
            == resolve_traversal_backend(None))
    with pytest.raises(ValueError, match="traversal_backend"):
        resolve_traversal_backend("gpu")


# -------------------------------------------------- golden: whole geometry --
def _assert_geometry_identical(geo_h, geo_d):
    np.testing.assert_array_equal(geo_d.bytes_matrix, geo_h.bytes_matrix)
    np.testing.assert_allclose(geo_d.slack, geo_h.slack, rtol=1e-4,
                               atol=1e-7)
    for rh, rd in zip(geo_h.receivers, geo_d.receivers):
        assert (rh is None) == (rd is None)
        if rh is None:
            continue
        for ih, id_ in ((rh.local, rd.local),
                        *((a.inter, b.inter)
                          for a, b in zip(rh.remote, rd.remote))):
            np.testing.assert_array_equal(id_.m2l_a, ih.m2l_a)
            np.testing.assert_array_equal(id_.m2l_b, ih.m2l_b)
            np.testing.assert_array_equal(id_.m2p_b, ih.m2p_b)
            assert len(id_.p2p_blocks) == len(ih.p2p_blocks)
            for bh, bd in zip(ih.p2p_blocks, id_.p2p_blocks):
                np.testing.assert_array_equal(bd.t_idx, bh.t_idx)
                np.testing.assert_array_equal(bd.s_idx, bh.s_idx)
    # byte-identical LETs (extraction is traversal-independent, pinned here
    # as the acceptance criterion demands)
    assert set(geo_d.lets) == set(geo_h.lets)
    for k, lh in geo_h.lets.items():
        ld = geo_d.lets[k]
        for f in ("center", "radius", "M", "child_start", "n_child",
                  "body_start", "n_body", "truncated", "x", "q"):
            np.testing.assert_array_equal(getattr(ld, f), getattr(lh, f))


@pytest.mark.parametrize("method,nparts", [("orb", 4), ("morton", 4)])
def test_plan_geometry_device_backend_matches_host(method, nparts):
    x = make_distribution("sphere", 1200, seed=7)
    q = np.random.default_rng(8).uniform(-1, 1, 1200)
    spec = PartitionSpec(nparts=nparts, method=method, ncrit=48)
    geo_h = plan_geometry(x, q, spec)                       # host default
    geo_d = plan_geometry(x, q, spec, traversal_backend="device")
    _assert_geometry_identical(geo_h, geo_d)
    # identical plans => byte-identical executed potentials
    np.testing.assert_array_equal(execute_geometry(geo_d),
                                  execute_geometry(geo_h))


def test_plan_geometry_device_backend_empty_partition_sentinels():
    """Morton with duplicated clusters: >= 3 empty partitions carry the
    inf/-inf sentinel boxes; the device backend must skip them exactly like
    the host path."""
    pts = np.array([[.1, .1, .1], [.8, .2, .3], [.3, .9, .5],
                    [.6, .6, .9], [.9, .9, .1]])
    x = np.repeat(pts, 60, axis=0)
    q = np.random.default_rng(1).uniform(-1, 1, len(x))
    spec = PartitionSpec(nparts=8, method="morton", ncrit=64)
    geo_h = plan_geometry(x, q, spec)
    geo_d = plan_geometry(x, q, spec, traversal_backend="device")
    empty = [p for p in range(8) if len(geo_d.owners[p]) == 0]
    assert len(empty) >= 3
    for p in empty:
        assert np.all(geo_d.boxes[p, 0] == np.inf)
        assert np.all(geo_d.boxes[p, 1] == -np.inf)
        assert geo_d.receivers[p] is None
    _assert_geometry_identical(geo_h, geo_d)


# --------------------------------------------- Pallas MAC kernel (interpret) -
def test_mac_kernel_interpret_smoke():
    """The Pallas MAC scoring path (use_kernel=True, interpret mode — what
    CPU CI exercises; TPU runs compile the same kernel) must agree with the
    jnp reference route bit-for-bit through the whole traversal."""
    _, _, t = _problem(n=600, ncrit=32)
    ref = device_dual_traversal(t, t, 0.5, use_kernel=False)
    ker = device_dual_traversal(t, t, 0.5, use_kernel=True, interpret=True)
    for a, b in zip(ref[:3], ker[:3]):
        np.testing.assert_array_equal(b, a)
    assert ref[3] == ker[3]
    m2l_h, p2p_h = dual_traversal(t, t, 0.5)
    np.testing.assert_array_equal(ker[0], m2l_h)
    np.testing.assert_array_equal(ker[1], p2p_h)


def test_mac_margins_kernel_matches_reference():
    import jax.numpy as jnp
    from repro.kernels.mac import mac_margins, mac_margins_ref
    rng = np.random.default_rng(0)
    ca = jnp.asarray(rng.uniform(-1, 1, (256, 3)).astype(np.float32))
    cb = jnp.asarray(rng.uniform(-1, 1, (256, 3)).astype(np.float32))
    ra = jnp.asarray(rng.uniform(0, .2, 256).astype(np.float32))
    rb = jnp.asarray(rng.uniform(0, .2, 256).astype(np.float32))
    got = np.asarray(mac_margins(ca, ra, cb, rb, 0.5, interpret=True))
    ref = np.asarray(mac_margins_ref(ca, ra, cb, rb, 0.5))
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-7)
    with pytest.raises(ValueError, match="multiple"):
        mac_margins(ca[:100], ra[:100], cb[:100], rb[:100], 0.5)
