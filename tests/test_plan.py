"""Plan/execute subsystem: golden equivalence of the vectorized host-geometry
layer against the retained reference loop implementations, plan-reuse
correctness, and the end-to-end distributed pipeline."""
import numpy as np
import pytest

from repro.core.distributed_fmm import (build_distributed_plan,
                                        execute_distributed_plan,
                                        run_distributed_fmm)
from repro.core.distributions import make_distribution
from repro.core.fmm import direct_potential, execute_fmm_plan, upward_pass
from repro.core.let import extract_let, extract_lets, graft
from repro.core.multipole import get_operators
from repro.core.partition.orb import orb_partition
from repro.core.plan import (build_fmm_plan, build_p2p_blocks, bucket_size,
                             padded_body_gather)
from repro.core.reference import (reference_build_tree,
                                  reference_dual_traversal,
                                  reference_extract_let,
                                  reference_pad_bodies,
                                  reference_padded_leaf_bodies)
from repro.core.traversal import dual_traversal
from repro.core.tree import build_tree

LET_FIELDS = ["center", "radius", "M", "child_start", "n_child",
              "body_start", "n_body", "truncated", "x", "q"]


def _mixed_distribution(n, seed):
    """Half volume (cube), half boundary (sphere surface) — the paper's
    boundary-distribution stress case."""
    rng = np.random.default_rng(seed)
    a = make_distribution("cube", n // 2, seed=seed)
    b = make_distribution("sphere", n - n // 2, seed=seed + 1)
    x = np.concatenate([a, b])
    return x[rng.permutation(len(x))]


def _pairset(pairs):
    return set(map(tuple, np.asarray(pairs).tolist()))


# ------------------------------------------------------ golden: tree -------
def test_build_tree_matches_reference():
    x = _mixed_distribution(3000, seed=11)
    q = np.random.default_rng(0).uniform(-1, 1, len(x))
    t = build_tree(x, q, ncrit=48)
    r = reference_build_tree(x, q, ncrit=48)
    # identical Morton sort
    assert np.array_equal(t.perm, r.perm)
    assert np.array_equal(t.x, r.x) and np.array_equal(t.q, r.q)
    assert t.n_cells == r.n_cells
    # identical cell geometry as a multiset (cell numbering is BFS vs DFS)
    def cells(tt):
        return sorted(zip(tt.body_start.tolist(), tt.n_body.tolist(),
                          tt.level.tolist(), tt.n_child.tolist(),
                          map(tuple, np.round(tt.bbox_min, 12).tolist()),
                          map(tuple, np.round(tt.bbox_max, 12).tolist())))
    assert cells(t) == cells(r)
    # children contiguous and consistent
    for c in np.nonzero(t.n_child)[0]:
        cs, nc = t.child_start[c], t.n_child[c]
        assert np.all(t.parent[cs:cs + nc] == c)
        assert t.n_body[cs:cs + nc].sum() == t.n_body[c]
        assert t.body_start[cs] == t.body_start[c]


def test_padded_leaf_bodies_matches_reference():
    x = _mixed_distribution(1500, seed=3)
    t = build_tree(x, np.ones(len(x)), ncrit=32)
    assert np.array_equal(t.padded_leaf_bodies(), reference_padded_leaf_bodies(t))
    # the plan-layer gather matches the seed's per-cell padding loop too
    cells = t.leaves
    idx, valid = padded_body_gather(t, cells, t.ncrit)
    assert np.array_equal(np.where(valid, idx, -1), reference_pad_bodies(t, cells))


# ------------------------------------------------- golden: traversal -------
@pytest.mark.parametrize("theta", [0.4, 0.5, 0.7])
def test_dual_traversal_matches_reference(theta):
    x = _mixed_distribution(2500, seed=17)
    t = build_tree(x, np.ones(len(x)), ncrit=32)
    m2l_v, p2p_v = dual_traversal(t, t, theta)
    m2l_r, p2p_r = reference_dual_traversal(t, t, theta)
    assert _pairset(m2l_v) == _pairset(m2l_r)
    assert _pairset(p2p_v) == _pairset(p2p_r)


def test_dual_traversal_grafted_matches_reference():
    """Traversal against a grafted LET (truncated cells -> M2P fallback)."""
    x = _mixed_distribution(3000, seed=23)
    q = np.random.default_rng(1).uniform(-1, 1, len(x))
    part, boxes = orb_partition(x, 4)
    ops = get_operators(4)
    i0, i1 = np.nonzero(part == 0)[0], np.nonzero(part == 1)[0]
    t0 = build_tree(x[i0], q[i0], ncrit=48)
    t1 = build_tree(x[i1], q[i1], ncrit=48)
    M0 = np.asarray(upward_pass(t0, ops))
    g = graft(extract_let(t0, M0, boxes[1, 0], boxes[1, 1], theta=0.5))
    v = dual_traversal(t1, g, 0.5, with_m2p=True)
    r = reference_dual_traversal(t1, g, 0.5, with_m2p=True)
    for a, b in zip(v, r):
        assert _pairset(a) == _pairset(b)


# ------------------------------------------------------- golden: LET -------
def test_extract_let_matches_reference_bytewise():
    x = _mixed_distribution(4000, seed=29)
    q = np.random.default_rng(2).uniform(-1, 1, len(x))
    part, boxes = orb_partition(x, 6)
    ops = get_operators(4)
    idx = np.nonzero(part == 0)[0]
    t = build_tree(x[idx], q[idx], ncrit=48)
    M = np.asarray(upward_pass(t, ops))
    others = np.arange(1, 6)
    batched = extract_lets(t, M, boxes[others, 0], boxes[others, 1], theta=0.5)
    for k, j in enumerate(others):
        ref = reference_extract_let(t, M, boxes[j, 0], boxes[j, 1], theta=0.5)
        one = extract_let(t, M, boxes[j, 0], boxes[j, 1], theta=0.5)
        for name in LET_FIELDS:
            assert np.array_equal(getattr(ref, name), getattr(one, name)), name
            assert np.array_equal(getattr(ref, name), getattr(batched[k], name)), name


# -------------------------------------------------- P2P width bucketing ----
def test_p2p_blocks_bucket_by_source_width():
    """One huge boundary leaf must not inflate every pair's padding."""
    x = _mixed_distribution(2000, seed=31)
    q = np.ones(len(x))
    t = build_tree(x, q, ncrit=32)
    _, p2p = dual_traversal(t, t, 0.5)
    blocks = build_p2p_blocks(t, t, p2p)
    assert sum(b.n for b in blocks) == len(p2p)
    widths = sorted(b.s_idx.shape[1] for b in blocks)
    # every block width is a power of two and covers its own leaves only
    for b in blocks:
        w = b.s_idx.shape[1]
        assert w & (w - 1) == 0
        pop = b.s_valid.sum(axis=1)[:b.n]
        assert pop.max() <= w and (b.n == 0 or pop.max() > w // 2 or w == 8)
    # a grafted-LET-like pathological case: widths differ across blocks when
    # leaf populations span more than one power-of-two bucket
    pops = t.n_body[np.asarray(p2p)[:, 1]]
    if bucket_size(int(pops.max()), lo=8) != bucket_size(int(pops.min()), lo=8):
        assert len(widths) > 1


# --------------------------------------------------------- plan reuse ------
def test_fmm_plan_reuse_identical_phi():
    x = _mixed_distribution(2000, seed=37)
    q = np.random.default_rng(3).uniform(-1, 1, len(x))
    t = build_tree(x, q, ncrit=48)
    plan = build_fmm_plan(t, t, theta=0.5, p=4)
    phi1 = execute_fmm_plan(plan)
    phi2 = execute_fmm_plan(plan)
    assert np.array_equal(phi1, phi2)
    ref = direct_potential(t.x, t.q)
    err = np.linalg.norm(phi1 - ref) / np.linalg.norm(ref)
    assert err < 2e-3, err


def test_distributed_plan_reuse_identical_phi():
    x = _mixed_distribution(2000, seed=41)
    q = np.random.default_rng(4).uniform(-1, 1, len(x))
    plan = build_distributed_plan(x, q, nparts=4, method="orb",
                                  protocol="hsdx", theta=0.5, ncrit=48)
    phi1 = execute_distributed_plan(plan)
    phi2 = execute_distributed_plan(plan)
    assert np.array_equal(phi1, phi2)


# ----------------------------------------------------------- end to end ----
def test_distributed_plan_matches_direct():
    n = 2000
    x = make_distribution("sphere", n, seed=5)   # boundary distribution
    q = np.random.default_rng(6).uniform(-1, 1, n)
    res = run_distributed_fmm(x, q, nparts=5, method="orb", protocol="hsdx",
                              theta=0.5, ncrit=48)
    ref = direct_potential(x, q)
    err = np.linalg.norm(res.phi - ref) / np.linalg.norm(ref)
    assert err < 3e-3, err


def test_distributed_single_partition_edge():
    """nparts=1: no remote boxes, batched extract_lets must handle G=0."""
    n = 800
    x = make_distribution("sphere", n, seed=9)
    q = np.ones(n)
    res = run_distributed_fmm(x, q, nparts=1, method="orb", protocol="alltoallv")
    ref = direct_potential(x, q)
    err = np.linalg.norm(res.phi - ref) / np.linalg.norm(ref)
    assert err < 3e-3, err


def test_sfc_box_inflation_parameter():
    """The SFC adjacency-box inflation is exposed end to end; a larger eps
    inflates the adjacency graph degree (more conservative neighbor sets)."""
    n = 1500
    x = make_distribution("sphere", n, seed=8)
    q = np.ones(n)
    r_small = run_distributed_fmm(x, q, nparts=4, method="hilbert",
                                  protocol="alltoallv", sfc_box_inflation=0.03)
    r_big = run_distributed_fmm(x, q, nparts=4, method="hilbert",
                                protocol="alltoallv", sfc_box_inflation=0.5)
    # physics identical (inflation only affects the adjacency graph)
    np.testing.assert_allclose(r_small.phi, r_big.phi, rtol=1e-12)
    assert r_big.adjacency_degree >= r_small.adjacency_degree
