"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + finiteness, plus a
prefill -> decode consistency check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.sharding.parallel import Parallelism

PAR = Parallelism(remat=False)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.1,
                                      jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vis"] = jnp.asarray(rng.normal(size=(B, cfg.n_vis_tokens, cfg.d_model)) * 0.1,
                                   jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, parts = jax.jit(lambda p, b: model.loss(p, b, PAR))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0
    h, _ = model.forward(params, batch, PAR)
    assert h.shape == (2, 32, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg, seed=1)

    def loss_of(p):
        return model.loss(p, batch, PAR)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                               for g in flat)))
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_prefill_decode_consistency(arch):
    """Decode with cache must match the full-sequence forward logits."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S, seed=2)
    cache, logits_pre = model.prefill(params, batch, PAR, S_max=S + 8)
    # decode the next token and compare against full forward over S+1
    next_tok = jnp.asarray([[5], [7]], jnp.int32)
    logits_dec, cache = model.decode_step(params, cache, next_tok, jnp.int32(S), PAR)
    full_tokens = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    batch_full = dict(batch, tokens=full_tokens)
    h, _ = model.forward(params, batch_full, PAR)
    from repro.models.transformer import logits_fn
    logits_full = logits_fn(params, h[:, -1:], cfg, PAR)
    got = np.asarray(logits_dec, np.float32)
    want = np.asarray(logits_full, np.float32)
    assert got.shape == want.shape
    err = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-6)
    assert err < 0.15, f"{arch}: decode/forward mismatch {err}"
