"""Partitioning: balance, histogram splitters, and the paper's central
demonstration — Hilbert discontinuity on boundary distributions vs ORB."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.distributions import make_distribution
from repro.core.partition.hot import histogram_splitters, hot_partition
from repro.core.partition.metrics import connected_components, load_balance, partition_report
from repro.core.partition.orb import find_splitter, orb_partition


def test_histogram_splitter_exact():
    rng = np.random.default_rng(0)
    vals = rng.uniform(-5, 3, 10_000)
    s = find_splitter(vals, 0.25)
    frac = (vals < s).mean()
    assert abs(frac - 0.25) < 0.002


@given(st.integers(2, 9), st.sampled_from(["cube", "sphere", "plummer"]))
@settings(max_examples=12, deadline=None)
def test_orb_balance_property(nparts, dist):
    """ORB multisection balances any distribution, any (non-pow2) nparts."""
    x = make_distribution(dist, 4000, seed=nparts)
    part, boxes = orb_partition(x, nparts)
    counts = np.bincount(part, minlength=nparts)
    assert counts.min() >= (4000 // nparts) - max(2, int(0.02 * 4000 / nparts))
    assert load_balance(part, nparts) < 1.05
    # tight boxes really contain their points
    for p in range(nparts):
        pts = x[part == p]
        assert np.all(pts >= boxes[p, 0] - 1e-12) and np.all(pts <= boxes[p, 1] + 1e-12)


@pytest.mark.parametrize("curve", ["hilbert", "morton"])
def test_hot_balance(curve):
    x = make_distribution("sphere", 8000, seed=3)
    part, _ = hot_partition(x, 16, curve=curve)
    assert load_balance(part, 16) < 1.15


def test_hilbert_weakness_on_boundary_distribution():
    """Paper §2.2 / Fig 3: Hilbert interval partitions of a *sphere surface*
    are spatially discontinuous; hybrid ORB partitions are compact."""
    n, nparts = 8000, 16
    x = make_distribution("sphere", n, seed=11)
    part_h, _ = hot_partition(x, nparts, curve="hilbert")
    part_o, _ = orb_partition(x, nparts)
    rep_h = partition_report(x, part_h, nparts)
    rep_o = partition_report(x, part_o, nparts)
    # ORB: every partition is a single spatial component
    assert rep_o["max_components"] == 1
    # Hilbert: at least one partition splits into disconnected islands
    assert rep_h["max_components"] > 1
    assert rep_h["mean_components"] > rep_o["mean_components"]


def test_hilbert_fine_on_uniform_cube():
    """The counterpoint the paper concedes: HOT is optimal for dense uniform
    volumes — partitions stay (mostly) connected."""
    x = make_distribution("cube", 8000, seed=13)
    part_h, _ = hot_partition(x, 8, curve="hilbert")
    rep = partition_report(x, part_h, 8)
    assert rep["mean_components"] <= 1.5
