"""Fused megakernel + AOT executable cache (repro.core.engine.fused /
repro.core.engine.exe_cache): warm evaluate()/step() pinned at exactly ONE
entry-computation launch, fused numerics pinned against the per-phase
engine, shape-class keying pinned hit/miss-exact, and the donation-vs-
residency contract (DeviceMemo views must never be donated) regression.

Compilation economics shape this module: every distinct shape-class key is
an XLA compile, so the tests share one module-scoped session + private
cache and then *count* cache traffic instead of recompiling per test."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.hlo_walk import count_entry_launches
from repro.core.api import (FMMSession, PartitionSpec, execute_geometry,
                            plan_geometry)
from repro.core.distributions import make_distribution
from repro.core.engine import (DeviceEngine, ExecutableCache,
                               default_fused_enabled)
from repro.core.engine import fused as fused_mod
from repro.core.engine.exe_cache import CompiledEntry

RTOL, ATOL = 1e-6, 2e-5         # x64 engine tolerances (test_engine.py)
F32_RTOL, F32_ATOL = 1e-4, 1e-4  # non-x64 fused path: device f32 accumulation


def _problem(n=700, seed=11, qseed=12, dist="sphere"):
    x = make_distribution(dist, n, seed=seed)
    q = np.random.default_rng(qseed).uniform(-1, 1, n)
    return x, q


@pytest.fixture(scope="module")
def shared():
    """One compiled fused session + its private cache, shared module-wide so
    launch/cache counters are asserted against known traffic instead of
    paying one XLA compile per test."""
    x, q = _problem()
    spec = PartitionSpec(nparts=3, ncrit=48)
    cache = ExecutableCache()
    sess = FMMSession.from_points(x, q, spec, engine=True, fused=True,
                                  use_kernels=False, exe_cache=cache)
    return {"x": x, "q": q, "spec": spec, "cache": cache, "sess": sess}


# ------------------------------------------------------------- numerics ----
def test_fused_matches_reference_f32(shared):
    """Non-x64 fused evaluate accumulates in device f32 — marginally looser
    than the per-phase host-f64 path, but must still track the reference
    executor at f32-accumulation tolerances."""
    ref = execute_geometry(shared["sess"].geometry)
    phi = shared["sess"].evaluate()
    np.testing.assert_allclose(phi, ref, rtol=F32_RTOL, atol=F32_ATOL)


def test_fused_matches_per_phase_x64():
    """With x64 the fused composite inlines the SAME traced kernels the
    per-phase engine launches one by one, accumulating in device f64 — it
    must match at the tight engine tolerances."""
    x, q = _problem(n=500, seed=21, qseed=22)
    geo = plan_geometry(x, q, PartitionSpec(nparts=3, ncrit=48))
    jax.config.update("jax_enable_x64", True)
    try:
        per_phase = DeviceEngine(geo, use_kernels=False, fused=False)
        fused = DeviceEngine(geo, use_kernels=False, fused=True,
                             exe_cache=ExecutableCache())
        want = np.asarray(per_phase.evaluate_device())
        got_dev = fused.evaluate_device()
        assert isinstance(got_dev, jax.Array)
        assert got_dev.shape == (geo.n,) and got_dev.dtype == jnp.float64
    finally:
        jax.config.update("jax_enable_x64", False)
    np.testing.assert_allclose(np.asarray(got_dev), want, rtol=RTOL,
                               atol=ATOL)


# -------------------------------------------------------- launch counting --
def test_fused_warm_evaluate_is_one_launch(shared):
    """Warm fused evaluate: exactly one dispatch through one executable
    whose compiled HLO holds exactly one ENTRY computation; the donated
    payload handle from the previous call is consumed (aliased storage)."""
    sess = shared["sess"]
    eng = sess.engine
    sess.evaluate()                       # ensure warm
    x_prev = eng._x_pad                   # previous launch's threaded output
    n_before = len(eng.launch_log)
    sess.evaluate()
    launches = eng.launch_log[n_before:]
    assert [kind for kind, _ in launches] == ["evaluate"]
    entry, _ = eng._entries[("evaluate", False)]
    assert count_entry_launches(entry.hlo_text) == 1
    assert entry.calls >= 2
    # donation really happened: the old handle's buffer was given to XLA
    assert x_prev.is_deleted()
    assert not eng._x_pad.is_deleted()


def test_fused_step_within_slack_is_one_launch(shared):
    """A within-slack step through the fused session is one dispatch of the
    step executable (restack + drift + changed fused into one donated entry
    computation), no rebuilds, and the following evaluate matches the
    per-phase engine stepped identically."""
    sess = shared["sess"]
    sess.evaluate()
    eng = sess.engine
    rng = np.random.default_rng(31)
    eps = float(sess.geometry.slack.min()) / 4
    new_x = shared["x"] + rng.uniform(-eps, eps, shared["x"].shape)

    n_before = len(eng.launch_log)
    rep = sess.step(new_x)
    assert rep.rebuilt == ()
    steps = [e for e in eng.launch_log[n_before:] if e[0] == "step"]
    assert len(steps) == 1
    entry, _ = eng._entries[("step", False)]
    assert count_entry_launches(entry.hlo_text) == 1

    pp = FMMSession.from_points(shared["x"], shared["q"], shared["spec"],
                                engine=True, fused=False, use_kernels=False)
    pp.evaluate()
    assert pp.step(new_x).rebuilt == ()
    np.testing.assert_allclose(sess.evaluate(), pp.evaluate(),
                               rtol=F32_RTOL, atol=F32_ATOL)


# --------------------------------------------------- shape-class caching ---
def test_second_same_shape_class_geometry_zero_compiles(shared):
    """A new geometry over byte-identical points shares the shape class —
    its session must be served from the executable cache with ZERO XLA
    compilations (the miss counter is the compilation meter)."""
    cache = shared["cache"]
    shared["sess"].evaluate()             # ensure the evaluate entry exists
    stats0 = cache.stats()
    sess2 = FMMSession.from_points(shared["x"].copy(), shared["q"].copy(),
                                   shared["spec"], engine=True, fused=True,
                                   use_kernels=False, exe_cache=cache)
    phi2 = sess2.evaluate()
    assert cache.misses == stats0["misses"]          # zero recompiles
    assert cache.hits == stats0["hits"] + 1          # one served resolution
    assert sess2.exe_cache_stats["misses"] == cache.misses
    # served-from-cache executable still computes the right answer
    np.testing.assert_allclose(phi2, execute_geometry(sess2.geometry),
                               rtol=F32_RTOL, atol=F32_ATOL)


def test_different_shape_class_geometry_compiles(shared):
    """Changing the partition count changes the stacked envelope shapes —
    a genuinely new shape class must MISS (one new compilation)."""
    cache = shared["cache"]
    misses0 = cache.misses
    sess = FMMSession.from_points(shared["x"], shared["q"],
                                  PartitionSpec(nparts=2, ncrit=48),
                                  engine=True, fused=True,
                                  use_kernels=False, exe_cache=cache)
    sess.evaluate()
    assert cache.misses == misses0 + 1


def test_executable_key_sensitivity():
    """The shape-class key must separate every compilation-relevant static
    and nothing else: theta buckets at 1/16 resolution, x64, backend,
    padded-dim digest, kernel statics."""
    kw = dict(n=100, n_parts=4, p=4, theta=0.5, x64=False, backend="cpu",
              use_kernels=False, interpret=None, block_ts=())
    base = fused_mod.executable_key("evaluate", "digest0", **kw)
    assert base == fused_mod.executable_key("evaluate", "digest0", **kw)
    assert base != fused_mod.executable_key("step", "digest0", **kw)
    assert base != fused_mod.executable_key("evaluate", "digest1", **kw)
    for field, value in [("n", 101), ("n_parts", 5), ("p", 6),
                         ("theta", 0.6), ("x64", True), ("backend", "tpu"),
                         ("use_kernels", True), ("block_ts", (128,))]:
        assert base != fused_mod.executable_key(
            "evaluate", "digest0", **{**kw, field: value}), field
    # thetas within one 1/16 bucket share the executable (same MAC geometry
    # class for compilation purposes; the tables carry the actual pairs)
    assert fused_mod.theta_bucket(0.5) == fused_mod.theta_bucket(0.52)
    assert base == fused_mod.executable_key("evaluate", "digest0",
                                            **{**kw, "theta": 0.52})


def test_exe_cache_lru_eviction_and_counters():
    """Pure cache semantics: LRU order refreshed on hit, eviction at the
    bound, counters exact, undersized bound rejected."""
    cache = ExecutableCache(maxsize=2)
    made = []

    def compiler(tag):
        def fn():
            made.append(tag)
            return object()       # stands in for jax.stages.Compiled
        return fn

    a = cache.get_or_compile("a", compiler("a"))
    cache.get_or_compile("b", compiler("b"))
    assert cache.get_or_compile("a", compiler("a2")) is a   # hit, no build
    cache.get_or_compile("c", compiler("c"))                # evicts LRU = b
    assert made == ["a", "b", "c"]
    assert "b" not in cache and "a" in cache and "c" in cache
    assert len(cache) == 2
    assert cache.stats() == {"hits": 1, "misses": 3, "evictions": 1,
                             "size": 2, "maxsize": 2}
    cache.get_or_compile("b", compiler("b2"))   # must recompile after evict
    assert made[-1] == "b2"
    assert isinstance(cache.get_or_compile("b", compiler("x")), CompiledEntry)
    with pytest.raises(ValueError, match="maxsize"):
        ExecutableCache(maxsize=0)


# ------------------------------------------------------ donation contract --
def test_donation_guard_rejects_memo_resident_view(shared):
    """DeviceMemo views are shared read-only state; donating one would let
    XLA delete a buffer every other consumer still reads.  `_donatable`
    must refuse them (the engine.fused donation-vs-residency contract
    documented at fmm.device_hook)."""
    eng = shared["sess"].engine
    view = eng._aa(eng.tables.up.tables["leaves"])    # memo-resident view
    assert eng.memo.is_resident(view)
    with pytest.raises(TypeError, match="donate"):
        eng._donatable(view)
    # host arrays upload as fresh copies — always donatable
    out = eng._donatable(np.zeros((4, 3)), jnp.float32)
    assert isinstance(out, jax.Array) and not eng.memo.is_resident(out)


def test_fused_interpret_smoke():
    """The Pallas kernel route INSIDE the fused composite (interpret mode,
    the CPU CI stand-in): bucketed P2P runs through p2p_pallas tiles instead
    of the jnp reference, AOT-lowered and compiled like any other entry —
    and still matches the reference executor."""
    x, q = _problem(n=260, seed=41, qseed=42)
    geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=32))
    eng = DeviceEngine(geo, use_kernels=True, interpret=True, fused=True,
                       exe_cache=ExecutableCache())
    phi = eng.evaluate()
    assert count_entry_launches(eng._entries[("evaluate", False)][0]
                                .hlo_text) == 1
    np.testing.assert_allclose(phi, execute_geometry(geo),
                               rtol=F32_RTOL, atol=F32_ATOL)


def test_fused_default_off_on_cpu():
    """CPU backends keep the per-phase engine default (its counters are
    pinned byte-exactly elsewhere); fused stays opt-in there."""
    if jax.default_backend() == "cpu":
        assert default_fused_enabled() is False
        x, q = _problem(n=200)
        geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=48))
        assert DeviceEngine(geo, use_kernels=False).fused is False
    else:
        assert default_fused_enabled() is True
