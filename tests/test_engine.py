"""Device evaluation engine (repro.core.engine): batched multi-tree upward
pass, segment-summed M2L, Pallas-bucketed P2P, and engine-backed session
dispatch — all pinned against the per-partition reference executors.

Tolerances: the engine's segment-summed M2L accumulates the same f32 terms
as the reference's per-plan scatters in a single launch, so sums regroup —
rtol 1e-6 with a small atol absorbs the f32 reassociation (the batched
upward pass itself is bitwise-identical, pinned below)."""
import warnings

import numpy as np
import pytest

import repro.core.api as api
import repro.core.fmm as fmm
from repro.core.api import (FMMSession, PartitionSpec, execute_geometry,
                            plan_geometry, sync_host_multipoles)
from repro.core.distributions import make_distribution
from repro.core.engine import DeviceEngine, build_batched_upward, stack_bodies
from repro.core.engine.upward import batched_upward
from repro.core.fmm import direct_potential, upward_pass
from repro.core.multipole import get_operators
from repro.core.tree import build_tree

RTOL, ATOL = 1e-6, 2e-5


def _problem(n=1500, seed=5, qseed=6, dist="sphere"):
    x = make_distribution(dist, n, seed=seed)
    q = np.random.default_rng(qseed).uniform(-1, 1, n)
    return x, q


def _clustered_problem():
    """Duplicated sites -> >= 3 of 8 morton partitions empty (inf/-inf
    sentinel boxes)."""
    pts = np.array([[.1, .1, .1], [.8, .2, .3], [.3, .9, .5],
                    [.6, .6, .9], [.9, .9, .1]])
    x = np.repeat(pts, 60, axis=0)
    q = np.random.default_rng(1).uniform(-1, 1, len(x))
    return x, q


# ------------------------------------------------- batched upward pass -----
def test_batched_upward_bitwise_matches_per_partition():
    """One vmapped launch over stacked schedules must reproduce every
    partition's per-tree upward_pass exactly (same traced closures, padding
    rows contribute exactly 0)."""
    x, q = _problem(n=1200, dist="plummer")
    geo = plan_geometry(x, q, PartitionSpec(nparts=4, ncrit=48))
    sched = build_batched_upward(geo.trees, geo.scheds)
    xp, qp = stack_bodies(geo.trees, sched.n_bodies_max)
    M = np.asarray(batched_upward(get_operators(geo.p), xp, qp, sched))
    for j, t in enumerate(geo.trees):
        ref = geo.Ms[j]
        np.testing.assert_array_equal(M[j, :ref.shape[0]], ref)
        assert not M[j, ref.shape[0]:].any()       # padding rows exactly 0


# --------------------------------------------------- engine vs reference ---
@pytest.mark.parametrize("method,nparts", [("orb", 5), ("morton", 4)])
def test_engine_allclose_reference(method, nparts):
    x, q = _problem()
    geo = plan_geometry(x, q, PartitionSpec(nparts=nparts, method=method,
                                            ncrit=48))
    ref = execute_geometry(geo)
    phi = DeviceEngine(geo, use_kernels=False).evaluate()
    np.testing.assert_allclose(phi, ref, rtol=RTOL, atol=ATOL)
    d = direct_potential(x, q)
    assert np.linalg.norm(phi - d) / np.linalg.norm(d) < 3e-3


def test_engine_with_empty_partitions_matches_reference():
    x, q = _clustered_problem()
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton",
                                            ncrit=64))
    empty = [p for p in range(8) if len(geo.owners[p]) == 0]
    assert len(empty) >= 3
    for p in empty:                                # inf/-inf sentinel boxes
        assert np.all(geo.boxes[p, 0] == np.inf)
        assert np.all(geo.boxes[p, 1] == -np.inf)
    phi = DeviceEngine(geo, use_kernels=False).evaluate()
    np.testing.assert_allclose(phi, execute_geometry(geo), rtol=RTOL,
                               atol=ATOL)


def test_engine_single_partition_matches_reference():
    x, q = _problem(n=400, dist="cube")
    geo = plan_geometry(x, q, PartitionSpec(nparts=1, ncrit=32))
    phi = DeviceEngine(geo, use_kernels=False).evaluate()
    np.testing.assert_allclose(phi, execute_geometry(geo), rtol=RTOL,
                               atol=ATOL)


# ------------------------------------------- session dispatch / stepping ---
def test_session_engine_dispatch_matches_reference_session():
    x, q = _problem(n=1200)
    spec = PartitionSpec(nparts=4, ncrit=48)
    phi_ref = FMMSession.from_points(x, q, spec, engine=False).potentials().phi
    sess = FMMSession.from_points(x, q, spec, engine=True, use_kernels=False)
    res = sess.potentials("hsdx")
    np.testing.assert_allclose(res.phi, phi_ref, rtol=RTOL, atol=ATOL)
    # protocol sweep still serves every protocol from the one evaluation
    sweep = sess.sweep()
    assert all(sweep[p].phi is res.phi for p in sweep)
    # the engine rides the session memo: one transfer meter for both paths
    assert sess.engine.memo is sess.memo


def test_engine_step_zero_multipole_transfers(monkeypatch):
    """Acceptance criterion: after warmup, a within-slack step issues no
    per-partition host transfers — revalidation is ONE batched device launch
    fed by a single new_x upload (+3 one-time frozen tables on the first
    step), the restacked device payload is reused for evaluation, and zero
    host upward_pass calls / multipole uploads happen."""
    x, q = _problem()
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48),
                                  engine=True, use_kernels=False)
    phi0 = sess.evaluate()
    eng = sess.engine
    misses0 = eng.memo.misses
    assert np.array_equal(sess.evaluate(), phi0)   # warm: zero transfers
    assert eng.memo.misses == misses0

    eps = float(sess.geometry.slack.min())
    assert eps > 0
    rng = np.random.default_rng(0)
    x1 = x + rng.uniform(-eps / 4, eps / 4, size=x.shape)
    calls = []
    real = api.upward_pass
    monkeypatch.setattr(api, "upward_pass",
                        lambda *a, **k: calls.append(a) or real(*a, **k))
    rep = sess.step(x1)
    assert rep.rebuilt == () and len(rep.refreshed) == 4
    assert calls == []                  # no host multipole recompute
    assert sess.geometry.Ms_stale == (0, 1, 2, 3)
    phi1 = sess.potentials("hsdx").phi
    assert eng.payload_refreshes == 1
    # first step: new_x + the three one-time revalidation tables (x_ref
    # envelope and the orig->flat gather pair) — NOTHING per-partition, and
    # evaluation reuses the device-restacked payload with zero extra uploads
    assert eng.memo.misses == misses0 + 4
    assert calls == []

    # steady state: each further within-slack step uploads exactly new_x
    misses1 = eng.memo.misses
    x2 = x1 + rng.uniform(-eps / 8, eps / 8, size=x.shape)
    rep2 = sess.step(x2)
    assert rep2.rebuilt == () and len(rep2.refreshed) == 4
    phi2 = sess.potentials("hsdx").phi
    assert eng.memo.misses == misses1 + 1
    assert calls == []

    ref = FMMSession.from_points(x, q, PartitionSpec(nparts=4, ncrit=48),
                                 engine=False)
    ref.step(x1)
    np.testing.assert_allclose(phi1, ref.potentials("hsdx").phi,
                               rtol=RTOL, atol=ATOL)
    ref.step(x2)
    np.testing.assert_allclose(phi2, ref.potentials("hsdx").phi,
                               rtol=RTOL, atol=ATOL)


def test_engine_step_with_charge_change_falls_back_to_host_revalidation():
    """The single-upload device revalidation path is position-only; a step
    that also rebinds charges must still agree with the reference session."""
    x, q = _problem(n=1000)
    spec = PartitionSpec(nparts=4, ncrit=48)
    sess = FMMSession.from_points(x, q, spec, engine=True, use_kernels=False)
    ref = FMMSession.from_points(x, q, spec, engine=False)
    sess.potentials()
    ref.potentials()
    eps = float(sess.geometry.slack.min())
    rng = np.random.default_rng(2)
    x1 = x + rng.uniform(-eps / 4, eps / 4, size=x.shape)
    q1 = q * 1.25
    rep = sess.step(x1, q1)
    ref.step(x1, q1)
    assert rep.rebuilt == ()
    np.testing.assert_allclose(sess.potentials("hsdx").phi,
                               ref.potentials("hsdx").phi, rtol=RTOL,
                               atol=ATOL)


# ------------------------------------------------ x64 device accumulation --
def test_engine_x64_device_accumulation_matches_reference():
    """Acceptance criterion: with x64 enabled the engine's segment sums stay
    on device and return ONE (N,) float64 device array matching the host-
    accumulated reference within the engine tolerances."""
    import jax
    import jax.numpy as jnp
    x, q = _problem(n=900)
    geo = plan_geometry(x, q, PartitionSpec(nparts=3, ncrit=48))
    ref = execute_geometry(geo)
    jax.config.update("jax_enable_x64", True)
    try:
        eng = DeviceEngine(geo, use_kernels=False)
        phi_dev = eng.evaluate_device()
        assert isinstance(phi_dev, jax.Array)
        assert phi_dev.shape == (geo.n,) and phi_dev.dtype == jnp.float64
        phi = eng.evaluate()               # same path, host boundary only
    finally:
        jax.config.update("jax_enable_x64", False)
    np.testing.assert_allclose(np.asarray(phi_dev), ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(phi, ref, rtol=RTOL, atol=ATOL)


def test_evaluate_device_requires_x64():
    x, q = _problem(n=300, dist="cube")
    geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=48))
    eng = DeviceEngine(geo, use_kernels=False)
    with pytest.raises(RuntimeError, match="x64"):
        eng.evaluate_device()


def test_engine_step_rebuild_syncs_host_mirrors():
    """A beyond-slack step after deferred refreshes must fill the host
    multipole mirrors before re-extracting LETs, then match the eagerly
    stepped reference session."""
    x, q = _problem()
    spec = PartitionSpec(nparts=4, ncrit=48)
    sess = FMMSession.from_points(x, q, spec, engine=True, use_kernels=False)
    ref = FMMSession.from_points(x, q, spec, engine=False)
    sess.potentials()
    ref.potentials()

    eps = float(sess.geometry.slack.min())
    rng = np.random.default_rng(0)
    x1 = x + rng.uniform(-eps / 4, eps / 4, size=x.shape)
    sess.step(x1)
    ref.step(x1)
    assert sess.geometry.Ms_stale != ()

    x2 = x1.copy()
    mover = 1
    x2[sess.geometry.owners[mover]] += np.array([0.15, -0.1, 0.2])
    rep = sess.step(x2)
    assert rep.rebuilt == (mover,)
    assert sess.geometry.Ms_stale == ()            # rebuild synced everything
    ref.step(x2)
    np.testing.assert_allclose(sess.potentials("hsdx").phi,
                               ref.potentials("hsdx").phi, rtol=RTOL,
                               atol=ATOL)
    d = direct_potential(x2, q)
    phi = sess.potentials("hsdx").phi
    assert np.linalg.norm(phi - d) / np.linalg.norm(d) < 3e-3


def test_reference_path_on_deferred_geometry_syncs_lazily():
    """Turning the engine off after deferred steps must transparently fill
    the host mirrors (sync_host_multipoles) and agree with an eager
    reference session."""
    x, q = _problem(n=1000)
    spec = PartitionSpec(nparts=4, ncrit=48)
    sess = FMMSession.from_points(x, q, spec, engine=True, use_kernels=False)
    sess.potentials()
    eps = float(sess.geometry.slack.min())
    x1 = x + np.random.default_rng(0).uniform(-eps / 4, eps / 4, size=x.shape)
    sess.step(x1)
    assert sess.geometry.Ms_stale != ()
    sess.engine_enabled = False                    # force reference dispatch
    phi = sess.evaluate()
    assert sess.geometry.Ms_stale == ()            # lazily synced
    ref = FMMSession.from_points(x, q, spec, engine=False)
    ref.step(x1)
    np.testing.assert_allclose(phi, ref.evaluate(), rtol=RTOL, atol=ATOL)


def test_sync_host_multipoles_idempotent_noop_when_fresh():
    x, q = _problem(n=400)
    geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=48))
    Ms_before = [None if M is None else M.copy() for M in geo.Ms]
    sync_host_multipoles(geo)
    for a, b in zip(geo.Ms, Ms_before):
        if a is not None:
            np.testing.assert_array_equal(a, b)


# -------------------------------------------------- pallas interpret smoke -
def test_engine_pallas_interpret_smoke():
    """Toy-size engine with the Pallas bucketed P2P path in interpret mode
    (what CPU CI runners can exercise; TPU runs compile the same kernels)."""
    x, q = _problem(n=300, dist="cube")
    geo = plan_geometry(x, q, PartitionSpec(nparts=3, ncrit=32))
    ref = execute_geometry(geo)
    phi = DeviceEngine(geo, use_kernels=True, interpret=True).evaluate()
    np.testing.assert_allclose(phi, ref, rtol=RTOL, atol=ATOL)


def test_p2p_autotune_cache_keyed_by_bucket_shape():
    from repro.kernels import p2p as kp
    kp._BLOCK_CACHE.clear()
    b1 = kp.best_block_t(64, 7, 32, interpret=True)
    b2 = kp.best_block_t(64, 7, 32, interpret=True)
    assert b1 == b2 and list(kp._BLOCK_CACHE) == [(64, 7, 32)]
    assert b1 in kp.BLOCK_CANDIDATES
    kp.best_block_t(128, 3, 32, interpret=True)
    assert len(kp._BLOCK_CACHE) == 2
    # same (S, n_pairs) with a different target width is a distinct class
    kp.best_block_t(64, 7, 512, interpret=True)
    assert len(kp._BLOCK_CACHE) == 3
    # the heuristic never exceeds its VMEM budget even when no candidate
    # covers T: S=1024 forces the last *fitting* candidate, not an overflow
    assert kp.best_block_t(1024, 2, 512, interpret=True) == 128
    # autotuned choices must produce identical numerics
    rng = np.random.default_rng(0)
    q = rng.uniform(-1, 1, (2, 64)).astype(np.float32)
    xs = rng.uniform(-1, 1, (2, 64, 3)).astype(np.float32)
    xt = rng.uniform(-1, 1, (2, 40, 3)).astype(np.float32)
    got = np.asarray(kp.p2p_pallas(q, xs, xt, interpret=True, block_t=256))
    ref = np.asarray(kp.p2p_pallas(q, xs, xt, interpret=True, block_t=128))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-6)


# ----------------------------------------------- deprecated use_pallas -----
def test_session_rejects_conflicting_kernel_flags():
    x, q = _problem(n=150, dist="cube")
    geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=48))
    with pytest.raises(ValueError, match="use_kernels only"):
        FMMSession(geo, use_kernels=True, use_pallas=False)


def test_use_pallas_flag_warns_once_and_is_honored():
    x, q = _problem(n=150, dist="cube")
    fmm._USE_PALLAS_WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        p1 = fmm.fmm_potential(x, q, ncrit=64, use_pallas=True)
        p2 = fmm.fmm_potential(x, q, ncrit=64, use_pallas=True)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "use_pallas" in str(w.message)]
    assert len(dep) == 1                           # once per call site
    assert "use_kernels" in str(dep[0].message)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_allclose(
        p1, fmm.fmm_potential(x, q, ncrit=64, use_kernels=True), rtol=2e-5,
        atol=2e-6)


# The hypothesis property sweep lives in test_engine_property.py (module-
# level importorskip would skip this whole file where hypothesis is absent).
