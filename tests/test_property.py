"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import protocols as proto
from repro.core.distributions import make_distribution
from repro.core.fmm import direct_potential, fmm_potential
from repro.core.hsdx import adjacency_from_boxes, build_comm_tree, relay_routes
from repro.core.partition.orb import orb_partition
from repro.core.tree import build_tree


@given(st.integers(0, 10_000), st.sampled_from(["cube", "sphere", "plummer"]),
       st.integers(16, 64))
@settings(max_examples=8, deadline=None)
def test_fmm_accuracy_invariant(seed, dist, ncrit):
    """FMM error stays bounded for any distribution/seed/leaf size."""
    n = 800
    x = make_distribution(dist, n, seed=seed)
    q = np.random.default_rng(seed).uniform(-1, 1, n)
    phi = fmm_potential(x, q, theta=0.5, ncrit=ncrit)
    ref = direct_potential(x, q)
    err = np.linalg.norm(phi - ref) / np.linalg.norm(ref)
    assert err < 3e-3, (dist, seed, ncrit, err)


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_tree_partition_of_unity(seed):
    """Any tree: leaves partition the bodies exactly; levels are consistent."""
    x = make_distribution("plummer", 700, seed=seed)
    t = build_tree(x, np.ones(700), ncrit=32)
    assert t.n_body[t.leaves].sum() == 700
    for c in range(1, t.n_cells):
        assert t.level[c] == t.level[t.parent[c]] + 1


@given(st.integers(2, 12), st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_relay_routes_reach_everyone(nparts, seed):
    """HSDX adjacency routes connect every ordered pair, and every hop is a
    Lemma-1 neighbor (communication strictly between adjacent partitions)."""
    x = make_distribution("sphere", 1200, seed=seed)
    _, _, boxes = orb_partition(x, nparts, regions=True)
    adj = adjacency_from_boxes(boxes)
    routes = relay_routes(adj)
    for (s, d), path in routes.items():
        assert path[0] == s and path[-1] == d
        for u, v in zip(path, path[1:]):
            assert v in adj[u] or (u, v) == (s, d), (path, u, v)


@given(st.integers(2, 10), st.data())
@settings(max_examples=15, deadline=None)
def test_any_bytes_matrix_delivered_by_all_protocols(P, data):
    """Protocol invariant: arbitrary sparse byte matrices are delivered
    identically by all four schedules."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    B = rng.integers(0, 3, (P, P)) * rng.integers(1, 10_000, (P, P))
    np.fill_diagonal(B, 0)
    boxes = np.array([[[i, 0, 0], [i + 1.0, 1, 1]] for i in range(P)])
    expect = {(i, j): int(B[i, j]) for i in range(P) for j in range(P)
              if i != j and B[i, j]}
    for name in proto.PROTOCOLS:
        sched = proto.make_schedule(name, B, boxes=boxes)
        assert proto.simulate_delivery(sched) == expect, name


@given(st.integers(1, 6), st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_comm_tree_is_spanning(nparts_log, seed):
    nparts = 2 * nparts_log + 1          # odd, non-pow2 too
    x = make_distribution("cube", 900, seed=seed)
    # region boxes share split planes (the Lemma-1 adjacency structure);
    # tight boxes may be disjoint and are only used for the MAC/LET
    _, _, boxes = orb_partition(x, nparts, regions=True)
    adj = adjacency_from_boxes(boxes)
    parent = build_comm_tree(adj, 0)
    # every node reaches the root
    for v in range(1, nparts):
        u, hops = v, 0
        while u != 0 and hops <= nparts:
            u = int(parent[u])
            hops += 1
            assert u >= 0, f"node {v} disconnected"
        assert u == 0
