"""Streaming P2P megakernel (repro.kernels.p2p_stream + the engine's
unified stream tables): interpret-mode BITWISE parity against the gathered
`p2p_pallas` kernel on identical slabs, stream-table invariants (ragged
width classes, dead padding tiles, partial tails, contiguity fallback),
engine equivalence stream-vs-gathered on both dispatch routes, the
donation-vs-residency contract for the stream index tables, and the warm
fused streaming evaluate pinned at exactly ONE entry-computation launch."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.hlo_walk import count_entry_launches
from repro.core.api import (FMMSession, PartitionSpec, execute_geometry,
                            plan_geometry)
from repro.core.distributions import make_distribution
from repro.core.engine import (DeviceEngine, ExecutableCache,
                               build_p2p_stream_tables, default_p2p_stream)
from repro.core.engine.p2p import (p2p_stream_gathered, p2p_stream_vals,
                                   stream_payload)
from repro.kernels.p2p import p2p_pallas
from repro.kernels.p2p_stream import p2p_stream

RTOL, ATOL = 1e-6, 2e-5          # x64 engine tolerances
F32_RTOL, F32_ATOL = 1e-4, 1e-4


def _problem(n=600, seed=5, qseed=6, dist="sphere"):
    """Boundary distribution: surface-heavy leaves give ragged source width
    classes (the paper's boundary-distribution regime, and the stress case
    for the unified stream table)."""
    x = make_distribution(dist, n, seed=seed)
    q = np.random.default_rng(qseed).uniform(-1, 1, n)
    return x, q


def _stream_fixture(n=500, nparts=3, ncrit=32, block_t=128):
    x, q = _problem(n=n)
    geo = plan_geometry(x, q, PartitionSpec(nparts=nparts, ncrit=ncrit))
    eng = DeviceEngine(geo, use_kernels=False, fused=False, p2p_stream=False)
    stream = build_p2p_stream_tables(eng.tables.p2p_buckets, block_t)
    assert stream is not None
    payload = np.asarray(stream_payload(
        jnp.asarray(eng._x_pad), jnp.asarray(eng._q_pad), stream["pad"]))
    return geo, eng, stream, payload


# --------------------------------------------------------- bitwise parity --
def test_stream_kernel_bitwise_vs_gathered_pallas():
    """The pinned tentpole invariant: the streaming kernel (in-kernel slab
    DMA, double-buffered pipeline, interpret mode) is BITWISE-equal to
    `p2p_pallas` run on the very slabs the DMAs would fetch — the gather
    moved into the kernel must change no bit of the result.  The geometry
    provides ragged width classes, partial target tails (tgt_len < block_t)
    and dead padding tiles."""
    _, _, stream, payload = _stream_fixture()
    meta = stream["meta"]
    bt, smax = stream["block_t"], stream["smax"]
    live = meta[:, 3] > 0
    assert live.any() and (~live).any()          # dead padding tiles exist
    assert (meta[live, 3] < bt).any()            # partial tails exist
    assert len({int(r) for r in meta[live, 1]}) > 1   # ragged source widths

    out = np.asarray(p2p_stream(jnp.asarray(meta), jnp.asarray(payload),
                                block_t=bt, smax=smax, n_buffers=2,
                                interpret=True))

    # gathered reference: identical slab values through p2p_pallas
    m = meta[live]
    lanes = np.arange(smax)
    qs = np.where(lanes[None, :] < m[:, 1:2],
                  payload[3][m[:, 0:1] + lanes[None, :]], 0.0)
    xs = payload[:3, m[:, 0:1] + lanes[None, :]].transpose(1, 2, 0)
    xt = payload[:3, m[:, 2:3] + np.arange(bt)[None, :]].transpose(1, 2, 0)
    ref = np.asarray(p2p_pallas(jnp.asarray(qs, jnp.float32),
                                jnp.asarray(xs), jnp.asarray(xt),
                                interpret=True, block_t=bt))
    assert np.array_equal(out[live].view(np.uint32), ref.view(np.uint32))
    assert np.all(out[~live] == 0.0)             # dead tiles: exact zeros

    # pipeline depth must not change a single bit either
    out3 = np.asarray(p2p_stream(jnp.asarray(meta), jnp.asarray(payload),
                                 block_t=bt, smax=smax, n_buffers=3,
                                 interpret=True))
    assert np.array_equal(out3.view(np.uint32), out.view(np.uint32))


def test_stream_gathered_xla_path_matches_kernel():
    """`p2p_stream_gathered` (the use_kernels=False streaming route) runs
    the same tile expression on the same slabs — allclose to the kernel at
    f32 tolerances (reduction order may differ across XLA programs)."""
    _, _, stream, payload = _stream_fixture()
    kern = np.asarray(p2p_stream(jnp.asarray(stream["meta"]),
                                 jnp.asarray(payload),
                                 block_t=stream["block_t"],
                                 smax=stream["smax"], interpret=True))
    xla = np.asarray(p2p_stream_gathered(jnp.asarray(stream["meta"]),
                                         jnp.asarray(payload),
                                         block_t=stream["block_t"],
                                         smax=stream["smax"]))
    np.testing.assert_allclose(xla, kern, rtol=F32_RTOL, atol=F32_ATOL)


# ------------------------------------------------------- table invariants --
def test_stream_tables_cover_exactly_the_bucket_work():
    """Every live (tile, lane) must map to exactly the target-body slots the
    gathered buckets cover, with identical multiplicity — the accumulation
    is a scatter-add, so coverage equality IS value equality."""
    _, eng, stream, _ = _stream_fixture()
    got = {}
    for i in range(stream["n_tiles"]):
        for lane in range(stream["block_t"]):
            if stream["out_valid"][i, lane]:
                k = int(stream["out_idx"][i, lane])
                got[k] = got.get(k, 0) + 1
    want = {}
    for b in eng.tables.p2p_buckets:
        live = b["mask"] != 0.0
        for r in np.nonzero(live)[0]:
            for t in b["t_idx"][r][b["t_valid"][r]]:
                want[int(t)] = want.get(int(t), 0) + 1
    assert got == want


def test_stream_tables_fallback_on_non_contiguous_rows():
    """A bucket whose source ids are not a contiguous run (synthetic: a
    permuted gather) must refuse the stream path — correctness never
    depends on the fast path."""
    _, eng, _, _ = _stream_fixture()
    buckets = [dict(b) for b in eng.tables.p2p_buckets]
    b0 = buckets[0]
    s_idx = b0["s_idx"].copy()
    r = int(np.nonzero(b0["mask"] != 0.0)[0][0])
    if b0["s_valid"][r].sum() >= 2:
        s_idx[r, [0, 1]] = s_idx[r, [1, 0]]      # break the run
    else:
        s_idx[r, 0] += 7
    b0["s_idx"] = s_idx
    assert build_p2p_stream_tables(tuple(buckets), 128) is None
    assert build_p2p_stream_tables((), 128) is None   # no near field at all


def test_engine_falls_back_to_gathered_buckets(monkeypatch):
    """An engine asked to stream a geometry that cannot stream must fall
    back to the gathered buckets and still produce the right answer."""
    from repro.core import engine as eng_mod
    x, q = _problem(n=300)
    geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=32))
    monkeypatch.setattr(eng_mod, "build_p2p_stream_tables",
                        lambda buckets, bt: None)
    eng = DeviceEngine(geo, use_kernels=False, fused=False, p2p_stream=True)
    phi = eng.evaluate()
    assert eng.p2p_stream is False and eng._stream is None
    np.testing.assert_allclose(phi, execute_geometry(geo),
                               rtol=F32_RTOL, atol=F32_ATOL)


# --------------------------------------------------- engine equivalence ----
def test_stream_engine_matches_gathered_engine_x64():
    """Per-phase engine, stream vs gathered near field, x64 device f64
    accumulation: tight-tolerance equivalence on both dispatch routes
    (XLA slab program and interpret-mode kernel)."""
    x, q = _problem(n=500, seed=15, qseed=16)
    geo = plan_geometry(x, q, PartitionSpec(nparts=3, ncrit=48))
    jax.config.update("jax_enable_x64", True)
    try:
        want = np.asarray(DeviceEngine(geo, use_kernels=False, fused=False,
                                       p2p_stream=False).evaluate_device())
        got_xla = np.asarray(DeviceEngine(geo, use_kernels=False, fused=False,
                                          p2p_stream=True).evaluate_device())
        got_kern = np.asarray(DeviceEngine(geo, use_kernels=True,
                                           interpret=True, fused=False,
                                           p2p_stream=True).evaluate_device())
    finally:
        jax.config.update("jax_enable_x64", False)
    np.testing.assert_allclose(got_xla, want, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got_kern, want, rtol=RTOL, atol=ATOL)


def test_stream_session_matches_reference():
    """FMMSession(p2p_stream=True) end to end against the reference
    executor — the knob threads through api -> engine -> schedules."""
    x, q = _problem(n=400, seed=25, qseed=26)
    sess = FMMSession.from_points(x, q, PartitionSpec(nparts=2, ncrit=48),
                                  engine=True, use_kernels=False,
                                  fused=False, p2p_stream=True)
    assert sess.engine.p2p_stream is True
    np.testing.assert_allclose(sess.evaluate(),
                               execute_geometry(sess.geometry),
                               rtol=F32_RTOL, atol=F32_ATOL)


# ------------------------------------------------------- fused streaming ---
@pytest.fixture(scope="module")
def fused_stream():
    """One compiled fused streaming session + private cache, shared
    module-wide (every distinct shape class is an XLA compile)."""
    x, q = _problem(n=500, seed=35, qseed=36)
    spec = PartitionSpec(nparts=3, ncrit=48)
    cache = ExecutableCache()
    sess = FMMSession.from_points(x, q, spec, engine=True, fused=True,
                                  use_kernels=False, p2p_stream=True,
                                  exe_cache=cache)
    return {"x": x, "q": q, "spec": spec, "cache": cache, "sess": sess}


def test_warm_fused_stream_evaluate_is_one_launch(fused_stream):
    """Streaming near field inside the fused composite: warm evaluate stays
    exactly ONE entry-computation launch, the executable key carries the
    kernel variant, and the numerics still track the reference."""
    sess = fused_stream["sess"]
    phi = sess.evaluate()
    np.testing.assert_allclose(phi, execute_geometry(sess.geometry),
                               rtol=F32_RTOL, atol=F32_ATOL)
    eng = sess.engine
    n_before = len(eng.launch_log)
    eng.evaluate()                    # warm: second dispatch, same entry
    launches = eng.launch_log[n_before:]
    assert [kind for kind, _ in launches] == ["evaluate"]
    entry, tabs = eng._entries[("evaluate", False)]
    assert count_entry_launches(entry.hlo_text) == 1
    assert entry.calls >= 2
    assert entry.key[-1] == "stream"  # p2p_impl recorded in the shape key
    assert eng._stream is not None
    # no per-bucket gather tables were uploaded on the stream path
    assert "p2ps_meta" in tabs
    assert not any(k.startswith("p2p0") for k in tabs)


def test_second_stream_geometry_zero_recompiles(fused_stream):
    """A second same-shape-class geometry on the streaming path must be
    served from the executable cache with zero XLA compilations — the
    stream tables are part of the shape-class digest, so byte-identical
    points share the class."""
    cache = fused_stream["cache"]
    fused_stream["sess"].evaluate()
    stats0 = cache.stats()
    sess2 = FMMSession.from_points(
        fused_stream["x"].copy(), fused_stream["q"].copy(),
        fused_stream["spec"], engine=True, fused=True, use_kernels=False,
        p2p_stream=True, exe_cache=cache)
    phi2 = sess2.evaluate()
    assert cache.misses == stats0["misses"]
    assert cache.hits == stats0["hits"] + 1
    np.testing.assert_allclose(phi2, execute_geometry(sess2.geometry),
                               rtol=F32_RTOL, atol=F32_ATOL)


# ------------------------------------------------------ donation contract --
def test_stream_tables_never_donated(fused_stream):
    """The stream meta/index tables are DeviceMemo-resident frozen state —
    `_donatable` must refuse them exactly like every other index table
    (the engine.fused donation-vs-residency contract)."""
    eng = fused_stream["sess"].engine
    eng.evaluate()
    view = eng._aa(eng._stream["meta"])
    assert eng.memo.is_resident(view)
    with pytest.raises(TypeError, match="donate"):
        eng._donatable(view)
    view2 = eng._aa(eng._stream["out_idx"])
    with pytest.raises(TypeError, match="donate"):
        eng._donatable(view2)


def test_stream_obs_counters():
    """The DMA-tile/launch counters and the p2p.stream span land in the
    flight recorder when enabled."""
    from repro import obs
    x, q = _problem(n=300, seed=45, qseed=46)
    geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=32))
    tr = obs.configure(enabled=True)
    try:
        obs.reset()
        eng = DeviceEngine(geo, use_kernels=False, fused=False,
                           p2p_stream=True)
        eng.evaluate()
        counters = obs.metrics_snapshot()["counters"]
        assert counters.get("p2p.stream.launches", 0) == 1
        assert counters.get("p2p.stream.builds", 0) == 1
        live = eng._stream["n_live_tiles"]
        assert counters.get("p2p.stream.tiles", 0) == live
        assert counters.get("p2p.stream.dma_tiles", 0) == 2 * live
        assert tr.spans("engine.p2p_stream")
    finally:
        obs.configure(enabled=False)


def test_default_p2p_stream_off_cpu():
    if jax.default_backend() == "cpu":
        assert default_p2p_stream() is False
        x, q = _problem(n=200)
        geo = plan_geometry(x, q, PartitionSpec(nparts=2, ncrit=48))
        assert DeviceEngine(geo, use_kernels=False).p2p_stream is False
