"""Flight recorder (repro.obs): tracer, metrics, report surfaces.

In-process: span nesting/ordering, the disabled-mode zero-allocation pin,
chrome-trace schema, metrics-registry isolation, the non-raising stats
surfaces and the one-launch guarantee under tracing.  The 4-device exchange
probe (wire bytes == rank-aggregated `GeometryPlan.bytes_matrix`, finite
`model_drift` per protocol) runs in a subprocess so this process keeps a
single device.
"""
import json
import os
import subprocess
import sys
import textwrap
import tracemalloc

import numpy as np
import pytest

from repro import obs


def _toy_points(n=300, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, 3)), rng.uniform(-1, 1, n)


# ------------------------------------------------------------- tracer -----
def test_span_nesting_and_ordering():
    tr = obs.configure(enabled=True)
    with obs.span("outer", {"k": 1}):
        with obs.span("inner.a"):
            pass
        with obs.span("inner.b"):
            pass
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner.a", "inner.b", "outer"]
    outer = spans[2]
    assert outer.attrs == {"k": 1}
    assert spans[0].parent == outer.sid == spans[1].parent
    assert outer.parent == -1
    assert spans[0].sid < spans[1].sid          # monotonic ids
    for s in spans:
        assert s.t1_ns >= s.t0_ns >= 0
    # children are contained in the parent interval
    assert outer.t0_ns <= spans[0].t0_ns and spans[1].t1_ns <= outer.t1_ns


def test_span_set_merges_attrs_and_summary_aggregates():
    tr = obs.configure(enabled=True)
    for i in range(3):
        with obs.span("work", {"i": i}) as sp:
            sp.set({"extra": i * 10})
    assert tr.spans("work")[1].attrs == {"i": 1, "extra": 10}
    summ = tr.summary()
    assert summ["work"]["count"] == 3
    assert summ["work"]["total_s"] >= summ["work"]["max_s"] > 0
    assert summ["work"]["mean_s"] == pytest.approx(
        summ["work"]["total_s"] / 3)


def test_events_record_instants_with_parent_span():
    tr = obs.configure(enabled=True)
    with obs.span("phase") as sp:
        obs.event("probe", {"x": 1})
    evs = [e for e in tr.events if isinstance(e, dict)]
    assert len(evs) == 1 and evs[0]["name"] == "probe"
    assert evs[0]["parent"] == sp.sid
    assert evs[0]["attrs"] == {"x": 1}


def test_ring_drop_bounds_memory():
    tr = obs.configure(enabled=True, max_events=100)
    for i in range(500):
        obs.event("e")
    assert len(tr.events) <= 100
    assert tr.dropped >= 400


def test_disabled_mode_is_zero_allocation():
    """The overhead pin: with tracing off, span/event/counter calls on a hot
    loop must not allocate (NULL_SPAN singleton, early-return helpers)."""
    obs.configure(enabled=False)
    d = {"n": 7}                     # pre-built attrs, as the contract asks

    def hot(iters):
        for _ in iters:
            with obs.span("hot.loop", d):
                pass
            obs.event("hot.event", d)
            obs.counter_add("hot.counter")
            obs.observe("hot.hist", 1.0)

    import itertools
    hot(itertools.repeat(None, 100))            # warm any lazy init
    it = itertools.repeat(None, 10_000)
    tracemalloc.start()
    hot(it)
    current, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # 10k iterations x 4 calls: anything per-iteration would be >100 KB;
    # allow small constant noise from the tracemalloc machinery itself
    assert peak < 8192, f"disabled obs hot path allocated {peak} bytes"


def test_chrome_trace_schema():
    tr = obs.configure(enabled=True)
    with obs.span("a", {"n": 2}):
        obs.event("marker", {"why": "test"})
    ct = tr.to_chrome_trace()
    json.dumps(ct)                               # serializable
    assert ct["displayTimeUnit"] == "ms"
    assert ct["otherData"]["dropped_events"] == 0
    evs = ct["traceEvents"]
    assert len(evs) == 2
    for e in evs:
        assert e["ph"] in ("X", "i")
        assert isinstance(e["name"], str)
        assert e["ts"] >= 0 and "pid" in e and "tid" in e
        assert "sid" in e["args"] and "parent" in e["args"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs[0]["dur"] >= 0 and xs[0]["args"]["n"] == 2
    ins = [e for e in evs if e["ph"] == "i"]
    assert ins[0]["s"] == "t" and ins[0]["args"]["why"] == "test"


def test_tracer_disable_keeps_history_reset_drops_it():
    tr = obs.configure(enabled=True)
    with obs.span("kept"):
        pass
    obs.configure(enabled=False)
    assert not obs.enabled()
    assert obs.get_tracer() is tr and len(tr.spans("kept")) == 1
    obs.reset()
    assert obs.get_tracer() is None


# ------------------------------------------------------------- metrics ----
def test_metrics_counters_gauges_histograms():
    obs.configure(enabled=True)
    obs.counter_add("c", 2)
    obs.counter_add("c")
    obs.gauge_set("g", 4.5)
    for v in (1.0, 3.0):
        obs.observe("h", v)
    snap = obs.metrics_snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["gauges"]["g"] == 4.5
    h = snap["histograms"]["h"]
    assert (h["count"], h["sum"], h["min"], h["max"], h["mean"]) == \
        (2, 4.0, 1.0, 3.0, 2.0)


def test_metrics_disabled_records_nothing():
    obs.configure(enabled=False)
    obs.counter_add("never")
    assert obs.metrics_snapshot()["counters"] == {}


def test_metrics_family_conflict_raises():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    reg.counter_add("name")
    with pytest.raises(ValueError):
        reg.gauge_set("name", 1.0)


def test_metrics_reset_isolation():
    """The autouse fixture calls obs.reset(); a prior test's counters must
    never be visible (this test relies on the fixture having run)."""
    assert obs.metrics_snapshot()["counters"] == {}
    obs.configure(enabled=True)
    obs.counter_add("leaky")
    obs.reset()
    assert obs.metrics_snapshot()["counters"] == {}


# ----------------------------------------------------- session surfaces ---
def test_meshless_exchange_stats_is_structured_not_raising():
    from repro.core.api import FMMSession
    x, q = _toy_points()
    sess = FMMSession.from_points(x, q, nparts=4, engine=False)
    st = sess.exchange_stats                     # pre-PR-8: RuntimeError
    assert st["enabled"] is False
    assert "reason" in st and st["n_rounds"] == 0
    assert st["protocol"] == "bulk"


def test_meshless_report_structure():
    from repro.core.api import FMMSession
    obs.configure(enabled=True)
    x, q = _toy_points()
    sess = FMMSession.from_points(x, q, nparts=4, engine=False)
    sess.evaluate()
    rep = sess.report()
    assert rep["obs"]["enabled"] is True
    assert "session.evaluate" in rep["timings"]
    assert "plan.geometry" in rep["timings"]
    assert rep["metrics"]["counters"]["session.evaluations"] == 1
    assert rep["exchange"] == {"enabled": False, "protocols": {}}
    assert rep["launches"] == {"enabled": False}
    assert rep["memo"]["misses"] >= 0
    assert rep["geometry"]["bytes_matrix_total"] == \
        int(sess.geometry.bytes_matrix.sum())
    json.dumps(rep)                              # report must be exportable


def test_traced_fused_evaluate_still_one_entry_launch():
    """Tracing must not break the one-launch guarantee: spans fence nothing
    by default, and the fused entry still compiles to ONE entry
    computation."""
    from repro.analysis.hlo_walk import count_entry_launches
    from repro.core.api import FMMSession
    from repro.core.engine import ExecutableCache
    obs.configure(enabled=True)
    x, q = _toy_points(400, seed=2)
    sess = FMMSession.from_points(x, q, nparts=4, engine=True, fused=True,
                                  use_kernels=False,
                                  exe_cache=ExecutableCache())
    sess.evaluate()
    sess.evaluate()
    rep = sess.report()
    la = rep["launches"]["evaluate"]
    assert la["entry_computations"] == 1
    assert la["calls"] == 2
    assert rep["exe_cache"]["misses"] == 1       # one compile, ever
    assert rep["metrics"]["counters"]["exe_cache.misses"] == 1
    assert rep["metrics"]["counters"]["engine.fused_launches"] == 2
    assert "exe_cache.compile" in rep["timings"]
    assert "engine.fused_evaluate" in rep["timings"]


def test_plan_geometry_spans_nest_under_plan():
    from repro.core.api import PartitionSpec, plan_geometry
    tr = obs.configure(enabled=True)
    x, q = _toy_points()
    plan_geometry(x, q, PartitionSpec(nparts=4))
    parent = tr.spans("plan.geometry")[0]
    for sub in ("plan.partition", "plan.trees", "plan.lets",
                "plan.receivers"):
        sp = tr.spans(sub)
        assert len(sp) == 1 and sp[0].parent == parent.sid
    assert parent.attrs["nparts"] == 4


# ----------------------------------------- 4-device exchange probes -------
_PROBE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    from repro import obs
    obs.configure(enabled=True)
    from repro.core.api import FMMSession, PartitionSpec, plan_geometry
    from repro.launch.mesh import host_device_mesh

    mesh = host_device_mesh(4)
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 1, (800, 3)); x[:, 0] *= 4.0
    q = rng.uniform(-1, 1, 800)
    geo = plan_geometry(x, q, PartitionSpec(nparts=8, method="morton",
                                            ncrit=64))
    sess = FMMSession(geo, mesh=mesh, dist_protocol="bulk")
    rep = sess.report(measure_exchange=True, reps=2)

    lay = sess.dist.layout
    inter = int(sum(int(geo.bytes_matrix[i, j])
                    for i in range(len(lay.part_rank))
                    for j in range(len(lay.part_rank))
                    if lay.part_rank[i] != lay.part_rank[j]))
    out = {"inter_rank_bytes": inter,
           "rank_bytes_sum": int(lay.rank_bytes.sum()),
           "protocols": {}}
    for name, st in rep["exchange"]["protocols"].items():
        out["protocols"][name] = {
            "delivered_bytes": int(st["delivered_bytes"]),
            "moved_bytes": int(st["moved_bytes"]),
            "model_drift": float(st["model_drift"]),
            "measured_s": float(st["measured_s"]),
            "loggp_s": float(st["loggp_s"]),
            "n_rounds": int(st["n_rounds"]),
            "round_wire_bytes": [r["wire_bytes"] for r in st["rounds"]]}
    out["probe_events"] = sum(
        1 for e in obs.get_tracer().events
        if isinstance(e, dict) and e["name"] == "dist.exchange_probe")
    print(json.dumps(out))
""").strip()


@pytest.fixture(scope="module")
def probe_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _PROBE_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("protocol", ["bulk", "grain", "hsdx"])
def test_exchange_probe_wire_bytes_match_bytes_matrix(probe_results,
                                                      protocol):
    """The probe's delivered bytes must equal the inter-rank aggregation of
    `GeometryPlan.bytes_matrix` — the paper's byte accounting, measured."""
    st = probe_results["protocols"][protocol]
    assert st["delivered_bytes"] == probe_results["inter_rank_bytes"]
    assert st["delivered_bytes"] == probe_results["rank_bytes_sum"]
    # every round's wire payload is accounted (moved >= delivered; relays
    # count per hop)
    assert st["moved_bytes"] >= st["delivered_bytes"]
    assert len(st["round_wire_bytes"]) == st["n_rounds"]


@pytest.mark.parametrize("protocol", ["bulk", "grain", "hsdx"])
def test_exchange_probe_model_drift(probe_results, protocol):
    st = probe_results["protocols"][protocol]
    assert np.isfinite(st["model_drift"]) and st["model_drift"] > 0
    assert st["measured_s"] > 0 and st["loggp_s"] > 0
    assert st["model_drift"] == pytest.approx(
        st["measured_s"] / st["loggp_s"])


def test_exchange_probe_emitted_events(probe_results):
    assert probe_results["probe_events"] == 3    # one per protocol
