"""Host-side geometry micro-benchmark: seed loop implementations vs the
frontier-vectorized traversal/LET passes, plus plan build-once/execute-many.

Workload (the ISSUE acceptance case): a 20k-body sphere-surface (boundary)
distribution at 8 ORB partitions.  For every partition we run the local
dual traversal and the sender-side LET extraction to the 7 remote boxes —
once with the retained reference loops, once with the vectorized passes —
and report the aggregate speedup.  A second pair of rows times building an
`FMMPlan` vs re-executing it, showing the geometry work a reused plan skips.
"""
import os
import time

import numpy as np

from repro.core.distributions import make_distribution
from repro.core.fmm import execute_fmm_plan, upward_pass
from repro.core.let import extract_lets
from repro.core.multipole import get_operators
from repro.core.partition.orb import orb_partition
from repro.core.plan import build_fmm_plan
from repro.core.reference import (reference_dual_traversal,
                                  reference_extract_let)
from repro.core.traversal import dual_traversal
from repro.core.tree import build_tree


def _time(fn):
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def run(n: int | None = None, nparts: int = 8, theta: float = 0.5,
        ncrit: int = 64):
    n = n or int(os.environ.get("HOST_SIDE_N", 20000))
    x = make_distribution("sphere", n, seed=0)      # boundary distribution
    q = np.random.default_rng(1).uniform(-1, 1, n)
    part, boxes = orb_partition(x, nparts)
    ops = get_operators(4)
    trees, Ms = [], []
    for pid in range(nparts):
        idx = np.nonzero(part == pid)[0]
        t = build_tree(x[idx], q[idx], ncrit=ncrit)
        trees.append(t)
        Ms.append(np.asarray(upward_pass(t, ops)))

    def trav_vec():
        for t in trees:
            dual_traversal(t, t, theta)

    def trav_ref():
        for t in trees:
            reference_dual_traversal(t, t, theta)

    def let_vec():
        for i, t in enumerate(trees):
            others = np.array([j for j in range(nparts) if j != i])
            extract_lets(t, Ms[i], boxes[others, 0], boxes[others, 1], theta)

    def let_ref():
        for i, t in enumerate(trees):
            for j in range(nparts):
                if j != i:
                    reference_extract_let(t, Ms[i], boxes[j, 0], boxes[j, 1], theta)

    trav_vec()          # warm caches before timing
    us_tv = _time(trav_vec)
    us_tr = _time(trav_ref)
    us_lv = _time(let_vec)
    us_lr = _time(let_ref)

    t0 = trees[0]
    us_build = _time(lambda: build_fmm_plan(t0, t0, theta=theta, p=4))
    plan = build_fmm_plan(t0, t0, theta=theta, p=4)
    execute_fmm_plan(plan)          # warm the JIT cache
    us_exec = _time(lambda: execute_fmm_plan(plan))

    speedup = (us_tr + us_lr) / max(us_tv + us_lv, 1e-9)
    return [
        (f"host_traversal_ref_n{n}_p{nparts}", us_tr, ""),
        (f"host_traversal_vec_n{n}_p{nparts}", us_tv,
         f"speedup={us_tr / max(us_tv, 1e-9):.1f}x"),
        (f"host_let_ref_n{n}_p{nparts}", us_lr, ""),
        (f"host_let_vec_n{n}_p{nparts}", us_lv,
         f"speedup={us_lr / max(us_lv, 1e-9):.1f}x"),
        (f"host_geometry_total_n{n}_p{nparts}", us_tv + us_lv,
         f"speedup={speedup:.1f}x"),
        (f"fmm_plan_build_n{n}", us_build, "traversal+padding+schedules"),
        (f"fmm_plan_execute_n{n}", us_exec, "kernels+gathers only"),
    ]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
