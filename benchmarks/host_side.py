"""Host-side geometry micro-benchmark: seed loop implementations vs the
frontier-vectorized traversal/LET passes, plus plan build-once/execute-many
and the device-resident traversal / step-revalidation tiers.

Workload (the ISSUE acceptance case): a 20k-body sphere-surface (boundary)
distribution at 8 ORB partitions.  For every partition we run the local
dual traversal and the sender-side LET extraction to the 7 remote boxes —
once with the retained reference loops, once with the vectorized passes —
and report the aggregate speedup.  A second pair of rows times building an
`FMMPlan` vs re-executing it, showing the geometry work a reused plan skips.

Device rows (``--traversal-backend=device`` or always-on comparison rows):
the `lax.while_loop` + Pallas-MAC traversal of repro.core.engine.traversal
against the NumPy host loop, and a `FMMSession.step` revalidation microbench
for the all-partitions-within-slack case — per-partition NumPy loop vs the
engine's single batched drift launch.  On CPU the device rows run the same
XLA program an accelerator would compile; treat their absolute times as a
correctness-costed floor, not the accelerator win itself.
"""
import os
import sys
import time

import numpy as np

from repro.core.distributions import make_distribution
from repro.core.fmm import execute_fmm_plan, upward_pass
from repro.core.let import extract_lets
from repro.core.multipole import get_operators
from repro.core.partition.orb import orb_partition
from repro.core.plan import build_fmm_plan
from repro.core.reference import (reference_dual_traversal,
                                  reference_extract_let)
from repro.core.traversal import dual_traversal
from repro.core.tree import build_tree


def _time(fn):
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def _device_traversal_rows(trees, theta, us_host):
    """Host vs device dual-traversal wall time (warm, all partitions)."""
    from repro.core.engine.traversal import device_dual_traversal
    from repro.core.plan import bucket_size
    pad = bucket_size(max(t.n_cells for t in trees))

    def trav_dev():
        for t in trees:
            device_dual_traversal(t, t, theta, pad_cells=pad)

    trav_dev()                  # compile + autotune caps before timing
    us_dev = _time(trav_dev)
    return [
        ("dev_traversal_host", us_host, ""),
        ("dev_traversal_device", us_dev,
         f"host/device={us_host / max(us_dev, 1e-9):.2f}x"),
    ]


def _step_revalidation_rows(n, nparts, theta, ncrit):
    """`FMMSession.step` within-slack revalidation: per-partition NumPy loop
    (reference session) vs one batched device drift launch (engine session).
    Positions drift by slack/4 each step, so every partition refreshes and
    none rebuilds — the hot time-stepping path."""
    from repro.core.api import FMMSession, PartitionSpec
    x = make_distribution("sphere", n, seed=3)
    q = np.random.default_rng(4).uniform(-1, 1, n)
    spec = PartitionSpec(nparts=nparts, theta=theta, ncrit=ncrit)
    rows = []
    rng = np.random.default_rng(5)
    for label, engine in (("host", False), ("device", True)):
        sess = FMMSession.from_points(x, q, spec, engine=engine,
                                      use_kernels=False)
        sess.evaluate()                         # warm engine + memo
        eps = float(sess.geometry.slack.min()) / 4
        steps = [x + rng.uniform(-eps, eps, x.shape) for _ in range(4)]
        sess.step(steps[0])                     # warm jit of the drift path

        def run_steps(sess=sess, steps=steps):
            for s in steps[1:]:
                rep = sess.step(s)
                assert rep.rebuilt == ()

        us = _time(run_steps) / (len(steps) - 1)
        rows.append((f"step_revalidate_{label}_n{n}_p{nparts}", us, ""))
    rows[1] = (rows[1][0], rows[1][1],
               f"host/device={rows[0][1] / max(rows[1][1], 1e-9):.2f}x")
    return rows


def _fused_engine_rows(n, nparts, theta, ncrit):
    """Fused megakernel + AOT executable-cache rows (repro.core.engine.fused):
    cold lower+compile vs warm one-launch evaluate, fused vs per-phase warm
    latency, warm within-slack fused step, the streaming-near-field warm
    evaluate (unified stream table vs per-bucket gathers inside the same
    donated launch), and the second geometry of the SAME shape class — which must be served from the executable cache with
    zero XLA compilations (asserted via the miss counter)."""
    from repro.core.api import FMMSession, PartitionSpec, plan_geometry
    from repro.core.engine import ExecutableCache
    x = make_distribution("sphere", n, seed=6)
    q = np.random.default_rng(7).uniform(-1, 1, n)
    spec = PartitionSpec(nparts=nparts, theta=theta, ncrit=ncrit)
    cache = ExecutableCache()

    sess = FMMSession(plan_geometry(x, q, spec), engine=True, fused=True,
                      use_kernels=False, exe_cache=cache)
    us_cold = _time(sess.evaluate)          # lower + XLA compile + launch
    us_warm = _time(sess.evaluate)          # ONE entry-computation launch

    pp = FMMSession(plan_geometry(x, q, spec), engine=True, fused=False,
                    use_kernels=False)
    pp.evaluate()                           # warm the per-phase jits
    us_pp = _time(pp.evaluate)

    rng = np.random.default_rng(8)
    eps = float(sess.geometry.slack.min()) / 4
    sess.step(x + rng.uniform(-eps, eps, x.shape))   # compile the step entry
    step_x = x + rng.uniform(-eps, eps, x.shape)
    us_step = _time(lambda: sess.step(step_x))       # ONE launch, within slack

    # streaming near field inside the fused composite (ISSUE 9 before/after:
    # unified stream table vs per-bucket gathers, same one-launch contract)
    ssess = FMMSession(plan_geometry(x, q, spec), engine=True, fused=True,
                       use_kernels=False, p2p_stream=True, exe_cache=cache)
    ssess.evaluate()                        # compile the streaming entry
    us_stream = _time(ssess.evaluate)

    misses0 = cache.misses
    sess2 = FMMSession(plan_geometry(x.copy(), q.copy(), spec), engine=True,
                       fused=True, use_kernels=False, exe_cache=cache)
    us_second = _time(sess2.evaluate)       # warm-cache cold start
    zero_recompile = cache.misses == misses0
    assert zero_recompile, \
        f"second same-shape-class geometry recompiled: {cache.stats()}"
    return [
        (f"fused_compile_cold_n{n}_p{nparts}", us_cold,
         "lower+compile+launch"),
        (f"fused_evaluate_warm_n{n}_p{nparts}", us_warm,
         f"cold/warm={us_cold / max(us_warm, 1e-9):.1f}x"),
        (f"fused_evaluate_warm_stream_n{n}_p{nparts}", us_stream,
         f"gathered/stream={us_warm / max(us_stream, 1e-9):.2f}x"),
        (f"perphase_evaluate_warm_n{n}_p{nparts}", us_pp,
         f"perphase/fused={us_pp / max(us_warm, 1e-9):.2f}x"),
        (f"fused_step_warm_n{n}_p{nparts}", us_step, ""),
        (f"fused_second_geometry_first_eval_n{n}_p{nparts}", us_second,
         f"cache_hits={cache.hits};misses={cache.misses};"
         f"zero_recompile={zero_recompile}"),
    ]


def _common_meta() -> dict:
    """The metadata header every BENCH_*.json carries (ISSUE 8 satellite):
    enough provenance to interpret a number months later — which commit,
    which backend, which jax, whether x64 was on, and when."""
    import datetime
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except Exception:
        sha = ""
    try:
        import jax
        backend = jax.default_backend()
        jax_version = jax.__version__
        x64 = bool(jax.config.jax_enable_x64)
    except Exception:
        backend, jax_version, x64 = "", "", False
    return {"git_sha": sha or "unknown", "backend": backend,
            "jax_version": jax_version, "x64": x64,
            "timestamp": datetime.datetime.now(
                datetime.timezone.utc).isoformat()}


def write_bench_json(rows, path, meta=None) -> str:
    """Persist benchmark rows as machine-readable BENCH_*.json (atomic
    rename), so the perf trajectory is tracked across PRs instead of
    scrolling away in CI logs.  Schema: {schema, unix_time, meta,
    rows: [{name, us_per_call, derived}]}.  `meta` is merged over the
    `_common_meta` provenance header shared by every benchmark."""
    import json
    payload = {
        "schema": "repro-bench-v1",
        "unix_time": time.time(),
        "meta": {**_common_meta(), **dict(meta or {})},
        "rows": [{"name": name, "us_per_call": us, "derived": derived}
                 for name, us, derived in rows],
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def run(n: int | None = None, nparts: int = 8, theta: float = 0.5,
        ncrit: int = 64, traversal_backend: str | None = None):
    n = n or int(os.environ.get("HOST_SIDE_N", 20000))
    x = make_distribution("sphere", n, seed=0)      # boundary distribution
    q = np.random.default_rng(1).uniform(-1, 1, n)
    part, boxes = orb_partition(x, nparts)
    ops = get_operators(4)
    trees, Ms = [], []
    for pid in range(nparts):
        idx = np.nonzero(part == pid)[0]
        t = build_tree(x[idx], q[idx], ncrit=ncrit)
        trees.append(t)
        Ms.append(np.asarray(upward_pass(t, ops)))

    def trav_vec():
        for t in trees:
            dual_traversal(t, t, theta)

    def trav_ref():
        for t in trees:
            reference_dual_traversal(t, t, theta)

    def let_vec():
        for i, t in enumerate(trees):
            others = np.array([j for j in range(nparts) if j != i])
            extract_lets(t, Ms[i], boxes[others, 0], boxes[others, 1], theta)

    def let_ref():
        for i, t in enumerate(trees):
            for j in range(nparts):
                if j != i:
                    reference_extract_let(t, Ms[i], boxes[j, 0], boxes[j, 1], theta)

    trav_vec()          # warm caches before timing
    us_tv = _time(trav_vec)
    us_tr = _time(trav_ref)
    us_lv = _time(let_vec)
    us_lr = _time(let_ref)

    t0 = trees[0]
    us_build = _time(lambda: build_fmm_plan(t0, t0, theta=theta, p=4))
    plan = build_fmm_plan(t0, t0, theta=theta, p=4)
    execute_fmm_plan(plan)          # warm the JIT cache
    us_exec = _time(lambda: execute_fmm_plan(plan))

    speedup = (us_tr + us_lr) / max(us_tv + us_lv, 1e-9)
    rows = [
        (f"host_traversal_ref_n{n}_p{nparts}", us_tr, ""),
        (f"host_traversal_vec_n{n}_p{nparts}", us_tv,
         f"speedup={us_tr / max(us_tv, 1e-9):.1f}x"),
        (f"host_let_ref_n{n}_p{nparts}", us_lr, ""),
        (f"host_let_vec_n{n}_p{nparts}", us_lv,
         f"speedup={us_lr / max(us_lv, 1e-9):.1f}x"),
        (f"host_geometry_total_n{n}_p{nparts}", us_tv + us_lv,
         f"speedup={speedup:.1f}x"),
        (f"fmm_plan_build_n{n}", us_build, "traversal+padding+schedules"),
        (f"fmm_plan_execute_n{n}", us_exec, "kernels+gathers only"),
    ]
    backend = (traversal_backend
               or os.environ.get("HOST_SIDE_TRAVERSAL", "host"))
    if backend == "device":
        rows += _device_traversal_rows(trees, theta, us_tv)
        rows += _step_revalidation_rows(min(n, 6000), min(nparts, 4), theta,
                                        ncrit)
    # fused megakernel + executable cache: toy size — the rows meter launch
    # and compile overhead, which does not need the full body count
    rows += _fused_engine_rows(min(n, 4000), min(nparts, 4), theta, ncrit)
    return rows


if __name__ == "__main__":
    backend = None
    fused_only = False
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_host_side.json")
    for a in sys.argv[1:]:
        if a.startswith("--traversal-backend="):
            backend = a.split("=", 1)[1]
        elif a == "--fused-only":       # CI warm-cache smoke: skip the
            fused_only = True           # 20k-body geometry sweep entirely
        elif a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
    if fused_only:
        n = int(os.environ.get("HOST_SIDE_N", 20000))
        out = _fused_engine_rows(min(n, 4000), 4, 0.5, 64)
    else:
        out = run(traversal_backend=backend)
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")
    if json_path:
        where = write_bench_json(out, json_path,
                                 meta={"module": "host_side",
                                       "fused_only": fused_only})
        print(f"# wrote {where}", file=sys.stderr)
