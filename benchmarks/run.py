# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import traceback

from benchmarks import (fig6_granularity, fig7_protocols, fig8_weak,
                        host_side, kernel_bench, partition_quality,
                        roofline_table, table3_hsdx)

MODULES = [
    ("host_side (plan vs loop geometry)", host_side),
    ("partition_quality (Fig 3 / §2.2)", partition_quality),
    ("fig6_granularity (Fig 6)", fig6_granularity),
    ("table3_hsdx (Table 3)", table3_hsdx),
    ("fig7_protocols (Fig 7)", fig7_protocols),
    ("fig8_weak (Fig 8)", fig8_weak),
    ("kernel_bench (P2P/attn/WKV)", kernel_bench),
    ("roofline_table (§Roofline)", roofline_table),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in MODULES:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failures += 1
            print(f"{label},-1,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
