# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Usage:  python benchmarks/run.py [filter ...] [--json=PATH] [--no-json]
# With no arguments every module runs; otherwise only modules whose label
# contains one of the (case-insensitive) filter substrings run — e.g.
# ``python benchmarks/run.py kernel`` runs just the kernel/engine sweep.
#
# Every run also persists the collected rows as machine-readable
# benchmarks/BENCH_run.json (git-ignored; see host_side.write_bench_json),
# so the perf trajectory — cold-compile, warm-evaluate, warm-step,
# fused-vs-per-phase — is tracked across PRs instead of scrolling away.
import os
import sys
import traceback

from benchmarks import (fig6_granularity, fig7_protocols, fig8_exchange,
                        fig8_weak, host_side, kernel_bench,
                        partition_quality, roofline_table, table3_hsdx)

MODULES = [
    ("host_side (plan vs loop geometry)", host_side),
    ("partition_quality (Fig 3 / §2.2)", partition_quality),
    ("fig6_granularity (Fig 6)", fig6_granularity),
    ("table3_hsdx (Table 3)", table3_hsdx),
    ("fig7_protocols (Fig 7)", fig7_protocols),
    ("fig8_weak (Fig 8)", fig8_weak),
    ("fig8_exchange (dist LET exchange, measured vs LogGP)", fig8_exchange),
    ("kernel_bench (bucketed P2P/attn/WKV + engine sweep)", kernel_bench),
    ("roofline_table (§Roofline)", roofline_table),
]


def main(argv=None) -> None:
    args = sys.argv[1:] if argv is None else argv
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_run.json")
    filters = []
    for a in args:
        if a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
        else:
            filters.append(a.lower())
    selected = [(label, mod) for label, mod in MODULES
                if not filters or any(f in label.lower() for f in filters)]
    if not selected:
        print(f"no benchmark matches {filters}; "
              f"labels: {[l for l, _ in MODULES]}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failures = 0
    collected = []
    for label, mod in selected:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
                collected.append((name, us, derived))
        except Exception:
            failures += 1
            print(f"{label},-1,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if json_path:
        where = host_side.write_bench_json(
            collected, json_path,
            meta={"modules": [label for label, _ in selected],
                  "failures": failures})
        print(f"# wrote {where}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
