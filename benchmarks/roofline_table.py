"""Roofline terms per (arch x shape) from the dry-run artifacts (if present).
derived = the three terms + dominant bottleneck.  Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""
from __future__ import annotations

import os

from repro.analysis.roofline import load_artifacts, roofline_from_artifact

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def run():
    rows = []
    if not os.path.isdir(ART):
        return [("roofline_table", 0.0, "no artifacts dir — run dryrun first")]
    for rec in load_artifacts(ART, pattern="__1pod"):
        if "error" in rec or "skipped" in rec:
            continue
        r = roofline_from_artifact(rec, rec.get("walked")
                                    if "dot_flops" in rec.get("walked", {}) else None)
        rows.append((f"roofline_{rec['arch']}_{rec['shape']}",
                     rec["compile_s"] * 1e6,
                     f"compute={r['compute_s']*1e3:.2f}ms;"
                     f"mem={r['memory_s']*1e3:.2f}ms;"
                     f"coll={r['collective_s']*1e3:.2f}ms;"
                     f"dominant={r['dominant']};"
                     f"frac={r['roofline_fraction']:.2f}"))
    return rows or [("roofline_table", 0.0, "no artifacts found")]
