"""Roofline terms per (arch x shape) from the dry-run artifacts (if present).
derived = the three terms + dominant bottleneck.  Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all

As a script: ``python benchmarks/roofline_table.py [--json=PATH|--no-json]``
— rows land in benchmarks/BENCH_roofline.json with the common provenance
header (host_side.write_bench_json), so the roofline trajectory is tracked
across PRs alongside the measured rows.
"""
from __future__ import annotations

import os
import sys

from repro.analysis.roofline import load_artifacts, roofline_from_artifact

ART = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def run():
    rows = []
    if not os.path.isdir(ART):
        return [("roofline_table", 0.0, "no artifacts dir — run dryrun first")]
    for rec in load_artifacts(ART, pattern="__1pod"):
        if "error" in rec or "skipped" in rec:
            continue
        r = roofline_from_artifact(rec, rec.get("walked")
                                    if "dot_flops" in rec.get("walked", {}) else None)
        rows.append((f"roofline_{rec['arch']}_{rec['shape']}",
                     rec["compile_s"] * 1e6,
                     f"compute={r['compute_s']*1e3:.2f}ms;"
                     f"mem={r['memory_s']*1e3:.2f}ms;"
                     f"coll={r['collective_s']*1e3:.2f}ms;"
                     f"dominant={r['dominant']};"
                     f"frac={r['roofline_fraction']:.2f}"))
    return rows or [("roofline_table", 0.0, "no artifacts found")]


if __name__ == "__main__":
    try:
        from benchmarks.host_side import write_bench_json
    except ImportError:          # run as `python benchmarks/roofline_table.py`
        from host_side import write_bench_json
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_roofline.json")
    for a in sys.argv[1:]:
        if a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
    out = run()
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")
    if json_path:
        where = write_bench_json(out, json_path,
                                 meta={"module": "roofline_table",
                                       "artifacts_dir": ART})
        print(f"# wrote {where}", file=sys.stderr)
