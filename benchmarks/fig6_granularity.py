"""Fig 6: average communication time as grain size varies.

Reproduces the tuning curve including the eager->rendezvous cliff: small
grains pay per-message overhead, bulk grains pay the rendezvous handshake;
the optimum sits below the 8 KB eager limit.  derived = modeled comm ms per
grain (LogGP with the Cray MPICH cliff) on the measured LET byte matrix."""
from __future__ import annotations

import time

import numpy as np

from repro.core import protocols as proto
from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution

GRAINS = [512, 1024, 2048, 4096, 8192, 16384, 65536, None]  # None = bulk


def run(n: int = 4000, nparts: int = 8):
    rows = []
    for dist in ("sphere", "cube"):
        x = make_distribution(dist, n, seed=5)
        q = np.ones(n) / n
        t0 = time.time()
        res = run_distributed_fmm(x, q, nparts=nparts, method="orb",
                                  protocol="alltoallv", check_delivery=False)
        base_us = (time.time() - t0) * 1e6
        B = res.bytes_matrix
        sched = proto.make_schedule("alltoallv", B)
        times = {}
        for g in GRAINS:
            times[g] = proto.loggp_time(sched, grain_bytes=g) * 1e3
        best = min(times, key=times.get)
        curve = ";".join(f"g{g or 'bulk'}={t:.3f}ms" for g, t in times.items())
        rows.append((f"fig6_grain_{dist}", base_us,
                     f"best_grain={best};{curve}"))
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.host_side import write_bench_json
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_fig6_granularity.json")
    for a in sys.argv[1:]:
        if a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
    rows = run(n=int(os.environ.get("FIG6_N", "4000")),
               nparts=int(os.environ.get("FIG6_PARTS", "8")))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    if json_path:
        where = write_bench_json(rows, json_path,
                                 meta={"module": "fig6_granularity"})
        print(f"# wrote {where}", file=sys.stderr)
