"""Fig 3 / §2.2: Hilbert-interval partitions of boundary distributions are
spatially discontinuous; hybrid ORB partitions are compact.  The derived
column is the mean connected components per partition (1.0 = compact) and
the total LET bytes each scheme induces."""
from __future__ import annotations

import time

import numpy as np

from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution
from repro.core.partition.hot import hot_partition
from repro.core.partition.metrics import partition_report
from repro.core.partition.orb import orb_partition


def run(n: int = 6000, nparts: int = 16):
    rows = []
    for dist in ("sphere", "ellipsoid", "cube"):
        x = make_distribution(dist, n, seed=3)
        q = np.ones(n) / n
        for method in ("hilbert", "morton", "orb"):
            t0 = time.time()
            if method == "orb":
                part, _ = orb_partition(x, nparts)
            else:
                part, _ = hot_partition(x, nparts, curve=method)
            dt = (time.time() - t0) * 1e6
            rep = partition_report(x, part, nparts)
            res = run_distributed_fmm(x, q, nparts=min(nparts, 8),
                                      method=method, protocol="alltoallv",
                                      check_delivery=False)
            rows.append((f"partition_{dist}_{method}", dt,
                         f"components={rep['mean_components']:.2f}"
                         f";balance={rep['balance']:.3f}"
                         f";let_MB={res.bytes_matrix.sum()/1e6:.2f}"))
    return rows
