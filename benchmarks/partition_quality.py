"""Fig 3 / §2.2: Hilbert-interval partitions of boundary distributions are
spatially discontinuous; hybrid ORB partitions are compact.  The derived
column is the mean connected components per partition (1.0 = compact) and
the total LET bytes each scheme induces."""
from __future__ import annotations

import time

import numpy as np

from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution
from repro.core.partition.hot import hot_partition
from repro.core.partition.metrics import partition_report
from repro.core.partition.orb import orb_partition


def run(n: int = 6000, nparts: int = 16):
    rows = []
    for dist in ("sphere", "ellipsoid", "cube"):
        x = make_distribution(dist, n, seed=3)
        q = np.ones(n) / n
        for method in ("hilbert", "morton", "orb"):
            t0 = time.time()
            if method == "orb":
                part, _ = orb_partition(x, nparts)
            else:
                part, _ = hot_partition(x, nparts, curve=method)
            dt = (time.time() - t0) * 1e6
            rep = partition_report(x, part, nparts)
            res = run_distributed_fmm(x, q, nparts=min(nparts, 8),
                                      method=method, protocol="alltoallv",
                                      check_delivery=False)
            rows.append((f"partition_{dist}_{method}", dt,
                         f"components={rep['mean_components']:.2f}"
                         f";balance={rep['balance']:.3f}"
                         f";let_MB={res.bytes_matrix.sum()/1e6:.2f}"))
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.host_side import write_bench_json
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_partition_quality.json")
    for a in sys.argv[1:]:
        if a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
    rows = run(n=int(os.environ.get("PARTQ_N", "6000")),
               nparts=int(os.environ.get("PARTQ_PARTS", "16")))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    if json_path:
        where = write_bench_json(rows, json_path,
                                 meta={"module": "partition_quality"})
        print(f"# wrote {where}", file=sys.stderr)
