"""Fig 7: strong scaling across communication protocols (scaled-down N).

derived = LogGP exchange ms per protocol at each partition count, plus the
host-work reuse factor of the layered API: `FMMSession.sweep()` plans the
geometry ONCE and derives all four protocol schedules from the frozen bytes
matrix, where the legacy path re-partitioned, re-treed and re-extracted per
protocol (~4x the host work).

Toy-size smoke (CI): FIG7_N=1500 FIG7_PARTS=4,8 python benchmarks/fig7_protocols.py
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.api import FMMSession, PartitionSpec, schedule_comm
from repro.core.distributions import make_distribution
from repro.core.protocols import PROTOCOLS


def run(n: int = 6000, parts=(8, 16, 32)):
    x = make_distribution("sphere", n, seed=9)
    q = np.ones(n) / n
    # warm the jitted upward-pass kernels so t_plan measures steady-state
    # host-geometry work, not one-time JAX compilation
    FMMSession.from_points(x, q, PartitionSpec(nparts=parts[0], method="orb"))
    rows = []
    for P in parts:
        t0 = time.time()
        sess = FMMSession.from_points(x, q, PartitionSpec(nparts=P,
                                                          method="orb"))
        t_plan = time.time() - t0
        sweep = sess.sweep(check_delivery=False)
        entries = [f"{name}={sweep[name].loggp_time*1e3:.3f}ms"
                   for name in PROTOCOLS]
        # host-work reuse: 4 x (plan + schedule) vs plan + 4 x schedule
        t0 = time.time()
        for name in PROTOCOLS:
            schedule_comm(sess.geometry, name, check_delivery=False)
        t_sched = (time.time() - t0) / len(PROTOCOLS)
        reuse = (len(PROTOCOLS) * (t_plan + t_sched)
                 / (t_plan + len(PROTOCOLS) * t_sched))
        entries.append(f"plan_reuse={reuse:.2f}x")
        rows.append((f"fig7_P{P}", t_sched * 1e6, ";".join(entries)))
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.host_side import write_bench_json
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_fig7_protocols.json")
    for a in sys.argv[1:]:
        if a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
    n = int(os.environ.get("FIG7_N", "6000"))
    parts = tuple(int(s) for s in
                  os.environ.get("FIG7_PARTS", "8,16,32").split(","))
    rows = run(n=n, parts=parts)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    if json_path:
        where = write_bench_json(rows, json_path,
                                 meta={"module": "fig7_protocols",
                                       "n": n, "parts": list(parts)})
        print(f"# wrote {where}", file=sys.stderr)
