"""Fig 7: strong scaling across communication protocols (scaled-down N).
derived = LogGP exchange ms per protocol at each partition count."""
from __future__ import annotations

import time

import numpy as np

from repro.core import protocols as proto
from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution


def run(n: int = 6000):
    x = make_distribution("sphere", n, seed=9)
    q = np.ones(n) / n
    rows = []
    for P in (8, 16, 32):
        res = run_distributed_fmm(x, q, nparts=P, method="orb",
                                  protocol="hsdx", check_delivery=False)
        B = res.bytes_matrix
        boxes = _boxes_from(x, P)
        t0 = time.time()
        entries = []
        for name in proto.PROTOCOLS:
            sched = proto.make_schedule(name, B, boxes=boxes)
            entries.append(f"{name}={proto.loggp_time(sched)*1e3:.3f}ms")
        wall_us = (time.time() - t0) * 1e6
        rows.append((f"fig7_P{P}", wall_us, ";".join(entries)))
    return rows


def _boxes_from(x, P):
    from repro.core.partition.orb import orb_partition
    _, boxes = orb_partition(x, P)
    return boxes
