"""Fig 8: weak scaling, big (15k particles/proc) and small (200/proc)
examples.  For the small case initialization/latency dominates and
alltoallv can win — the paper's own caveat, reproduced."""
from __future__ import annotations

import time

import numpy as np

from repro.core import protocols as proto
from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution
from repro.core.partition.orb import orb_partition


def run():
    rows = []
    for label, per_proc in (("big", 2000), ("small", 200)):
        for P in (4, 8, 16):
            n = per_proc * P
            x = make_distribution("sphere", n, seed=P)
            q = np.ones(n) / n
            t0 = time.time()
            res = run_distributed_fmm(x, q, nparts=P, method="orb",
                                      protocol="hsdx", check_delivery=False)
            wall_us = (time.time() - t0) * 1e6
            _, boxes = orb_partition(x, P)
            entries = []
            for name in ("hsdx", "pairwise", "alltoallv"):
                sched = proto.make_schedule(name, res.bytes_matrix, boxes=boxes)
                entries.append(f"{name}={proto.loggp_time(sched)*1e3:.3f}ms")
            rows.append((f"fig8_{label}_P{P}", wall_us, ";".join(entries)))
    return rows
