"""Pallas-kernel parity microbench: wall time of the interpret-mode kernel
vs the jnp oracle on CPU (TPU timings require hardware; interpret mode
validates numerics + BlockSpec indexing).  derived = max |err| vs oracle.

Also sweeps the engine execution tier: per-width-class bucketed P2P (the
engine's Pallas route vs the jnp reference route, reporting per-bucket
speedup — >1x only on real device backends; interpret mode runs the kernel
as traced Python), full engine-vs-reference geometry evaluation, and the
ISSUE 9 streaming-vs-gathered near-field comparison (unified stream-table
slab program + in-kernel-gather Pallas kernel vs the per-bucket gathered
route, with scatter-accumulated max_err between the paths).
Environment knobs: ENGINE_BENCH_N (bodies, default 1500), ENGINE_BENCH_PARTS
(default 4).  As a script: ``python benchmarks/kernel_bench.py
[--stream-only] [--json=PATH|--no-json]`` — rows land in
benchmarks/BENCH_kernels.json with the common provenance header."""
from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    # p2p
    q = jnp.asarray(rng.uniform(-1, 1, (4, 128)), jnp.float32)
    xs = jnp.asarray(rng.uniform(-1, 1, (4, 128, 3)), jnp.float32)
    xt = jnp.asarray(rng.uniform(-1, 1, (4, 128, 3)), jnp.float32)
    us = _time(ops.p2p_blocked, q, xs, xt)
    err = float(jnp.max(jnp.abs(ops.p2p_blocked(q, xs, xt) - ref.p2p_ref(q, xs, xt))))
    rows.append(("kernel_p2p_4x128", us, f"max_err={err:.2e}"))
    # flash attention
    qa = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    ka = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    va = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    us = _time(lambda a, b, c: ops.flash_attention(a, b, c), qa, ka, va)
    err = float(jnp.max(jnp.abs(ops.flash_attention(qa, ka, va)
                                - ref.attention_ref(qa, ka, va))))
    rows.append(("kernel_flash_attn_gqa", us, f"max_err={err:.2e}"))
    # rwkv
    r = jnp.asarray(rng.normal(size=(2, 128, 64)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.999, (2, 128, 64)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(2, 64)) * 0.1, jnp.float32)
    s0 = jnp.zeros((2, 64, 64), jnp.float32)
    us = _time(lambda *a: ops.rwkv6_wkv(*a)[0], r, k, v, w, u, s0)
    y1, _ = ops.rwkv6_wkv(r, k, v, w, u, s0)
    y2, _ = ref.wkv_ref(r, k, v, w, u, s0)
    rows.append(("kernel_rwkv6_wkv", us, f"max_err={float(jnp.max(jnp.abs(y1-y2))):.2e}"))
    rows.extend(_bucketed_p2p_rows(rng))
    rows.extend(_stream_rows())
    rows.extend(_engine_rows())
    return rows


def _stream_rows():
    """Streaming vs gathered near field on one geometry — the ISSUE 9
    before/after.  Three routes over the SAME leaf-pair work: (a) the
    gathered per-width-class bucket path (one XLA gather + one launch per
    width class), (b) the unified stream table as one XLA slab program
    (`p2p_stream_gathered`, the use_kernels=False streaming route), (c) the
    streaming Pallas kernel with in-kernel slab DMA (interpret-mode
    emulation on CPU — the honest slower row; the kernel wins only on real
    device backends).  max_err compares the scatter-accumulated per-body
    sums, the quantity the engine actually consumes."""
    from repro.core.api import PartitionSpec, plan_geometry
    from repro.core.distributions import make_distribution
    from repro.core.engine import DeviceEngine, build_p2p_stream_tables
    from repro.core.engine.p2p import (p2p_bucket_vals, p2p_stream_gathered,
                                       stream_payload)
    from repro.kernels.p2p_stream import p2p_stream
    n = int(os.environ.get("ENGINE_BENCH_N", "1500"))
    nparts = int(os.environ.get("ENGINE_BENCH_PARTS", "4"))
    x = make_distribution("sphere", n, seed=9)      # boundary distribution
    q = np.random.default_rng(10).uniform(-1, 1, n)
    geo = plan_geometry(x, q, PartitionSpec(nparts=nparts, ncrit=48))
    eng = DeviceEngine(geo, use_kernels=False, fused=False, p2p_stream=False)
    buckets = eng.tables.p2p_buckets
    stream = build_p2p_stream_tables(buckets, 128)
    if stream is None:
        return [(f"p2p_stream_vs_gathered_n{n}", 0.0,
                 "geometry cannot stream (non-contiguous rows)")]
    x_dev = jnp.asarray(eng._x_pad)
    q_dev = jnp.asarray(eng._q_pad)
    payload = stream_payload(x_dev, q_dev, stream["pad"])
    meta = jnp.asarray(stream["meta"])
    bt, smax = stream["block_t"], stream["smax"]

    def gathered():
        return [p2p_bucket_vals(x_dev, q_dev, b, use_kernels=False,
                                to_host=False) for b in buckets]

    xla_stream = jax.jit(lambda m, p: p2p_stream_gathered(
        m, p, block_t=bt, smax=smax))
    us_g = _time(lambda: gathered()[-1])
    us_x = _time(lambda: xla_stream(meta, payload))
    us_k = _time(lambda: p2p_stream(meta, payload, block_t=bt, smax=smax,
                                    n_buffers=2, interpret=ops.INTERPRET))

    # scatter-accumulate both paths to per-body sums for an honest max_err
    flat = payload.shape[1]
    phi_g = np.zeros(flat)
    for b, vals in zip(buckets, gathered()):
        v = np.asarray(vals)
        live = np.asarray(b["mask"]) != 0.0
        for r in np.nonzero(live)[0]:
            sel = b["t_valid"][r]
            np.add.at(phi_g, b["t_idx"][r][sel], v[r][sel])
    phi_s = np.zeros(flat)
    sv = np.asarray(p2p_stream_gathered(meta, payload, block_t=bt, smax=smax))
    ok = stream["out_valid"]
    np.add.at(phi_s, stream["out_idx"][ok], sv[ok])
    err = float(np.max(np.abs(phi_g - phi_s)))

    kernel_mode = "interpret" if ops.INTERPRET else "compiled"
    return [
        (f"p2p_gathered_buckets_n{n}_p{nparts}", us_g,
         f"width_classes={len(buckets)}"),
        (f"p2p_stream_xla_n{n}_p{nparts}", us_x,
         f"tiles={stream['n_live_tiles']}/{stream['n_tiles']} "
         f"speedup_vs_gathered={us_g / max(us_x, 1e-9):.2f}x "
         f"max_err={err:.2e}"),
        (f"p2p_stream_kernel_{kernel_mode}_n{n}_p{nparts}", us_k,
         f"n_buffers=2 speedup_vs_gathered={us_g / max(us_k, 1e-9):.2f}x"),
    ]


def _bucketed_p2p_rows(rng):
    """Engine P2P bucket shapes: Pallas (autotuned block) vs jnp reference,
    per source-width class — the per-bucket speedup the engine dispatch
    trades on (expect < 1x under CPU interpret mode)."""
    from repro.core.fmm import _p2p_vals
    rows = []
    for P, S, T in ((16, 8, 64), (8, 64, 64), (4, 256, 64)):
        q = jnp.asarray(rng.uniform(-1, 1, (P, S)), jnp.float32)
        xs = jnp.asarray(rng.uniform(-1, 1, (P, S, 3)), jnp.float32)
        xt = jnp.asarray(rng.uniform(-1, 1, (P, T, 3)), jnp.float32)
        mask = jnp.ones((P,), jnp.float32)
        us_pl = _time(lambda a, b, c: ops.p2p_auto(a, b, c), q, xs, xt)
        us_ref = _time(lambda a, b, c: _p2p_vals(c, b, a, mask), q, xs, xt)
        err = float(jnp.max(jnp.abs(ops.p2p_auto(q, xs, xt)
                                    - _p2p_vals(xt, xs, q, mask))))
        rows.append((f"p2p_bucket_S{S}_pairs{P}", us_pl,
                     f"jnp_us={us_ref:.1f} speedup={us_ref / us_pl:.2f}x "
                     f"max_err={err:.2e}"))
    return rows


def _engine_rows():
    """Full engine-vs-reference sweep on one geometry (jnp engine path on
    CPU; the Pallas route needs hardware to win)."""
    from repro.core.api import DeviceMemo, PartitionSpec, execute_geometry, \
        plan_geometry
    from repro.core.distributions import make_distribution
    from repro.core.engine import DeviceEngine
    n = int(os.environ.get("ENGINE_BENCH_N", "1500"))
    nparts = int(os.environ.get("ENGINE_BENCH_PARTS", "4"))
    x = make_distribution("sphere", n, seed=5)
    q = np.random.default_rng(6).uniform(-1, 1, n)
    geo = plan_geometry(x, q, PartitionSpec(nparts=nparts, ncrit=48))
    memo = DeviceMemo()
    eng = DeviceEngine(geo, use_kernels=False)
    phi_ref = execute_geometry(geo, asarray=memo)    # warm both paths
    phi_eng = eng.evaluate()
    t0 = time.time()
    reps = 3
    for _ in range(reps):
        execute_geometry(geo, asarray=memo)
    us_ref = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        eng.evaluate()
    us_eng = (time.time() - t0) / reps * 1e6
    err = float(np.max(np.abs(phi_ref - phi_eng)))
    return [(f"engine_vs_reference_n{n}_p{nparts}", us_eng,
             f"ref_us={us_ref:.1f} speedup={us_ref / us_eng:.2f}x "
             f"max_err={err:.2e}")]


if __name__ == "__main__":
    try:
        from benchmarks.host_side import write_bench_json
    except ImportError:            # run as `python benchmarks/kernel_bench.py`
        from host_side import write_bench_json
    stream_only = False
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_kernels.json")
    for a in sys.argv[1:]:
        if a == "--stream-only":   # CI interpret smoke: just the ISSUE 9
            stream_only = True     # streaming-vs-gathered comparison
        elif a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
    out = _stream_rows() if stream_only else run()
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")
    if json_path:
        where = write_bench_json(out, json_path,
                                 meta={"module": "kernel_bench",
                                       "stream_only": stream_only})
        print(f"# wrote {where}", file=sys.stderr)
