"""Pallas-kernel parity microbench: wall time of the interpret-mode kernel
vs the jnp oracle on CPU (TPU timings require hardware; interpret mode
validates numerics + BlockSpec indexing).  derived = max |err| vs oracle."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    # p2p
    q = jnp.asarray(rng.uniform(-1, 1, (4, 128)), jnp.float32)
    xs = jnp.asarray(rng.uniform(-1, 1, (4, 128, 3)), jnp.float32)
    xt = jnp.asarray(rng.uniform(-1, 1, (4, 128, 3)), jnp.float32)
    us = _time(ops.p2p_blocked, q, xs, xt)
    err = float(jnp.max(jnp.abs(ops.p2p_blocked(q, xs, xt) - ref.p2p_ref(q, xs, xt))))
    rows.append(("kernel_p2p_4x128", us, f"max_err={err:.2e}"))
    # flash attention
    qa = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    ka = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    va = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    us = _time(lambda a, b, c: ops.flash_attention(a, b, c), qa, ka, va)
    err = float(jnp.max(jnp.abs(ops.flash_attention(qa, ka, va)
                                - ref.attention_ref(qa, ka, va))))
    rows.append(("kernel_flash_attn_gqa", us, f"max_err={err:.2e}"))
    # rwkv
    r = jnp.asarray(rng.normal(size=(2, 128, 64)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.999, (2, 128, 64)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(2, 64)) * 0.1, jnp.float32)
    s0 = jnp.zeros((2, 64, 64), jnp.float32)
    us = _time(lambda *a: ops.rwkv6_wkv(*a)[0], r, k, v, w, u, s0)
    y1, _ = ops.rwkv6_wkv(r, k, v, w, u, s0)
    y2, _ = ref.wkv_ref(r, k, v, w, u, s0)
    rows.append(("kernel_rwkv6_wkv", us, f"max_err={float(jnp.max(jnp.abs(y1-y2))):.2e}"))
    return rows
