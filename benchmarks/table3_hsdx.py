"""Table 3: HSDX strong-scaling vs MPI_Alltoallv.

The paper scales 4k -> 64k cores on Shaheen; offline we scale the partition
count on a fixed problem, build the exact per-pair LET byte matrices, and
compare the LogGP-modeled exchange times.  derived mirrors the table rows:
relative speedup, efficiency, and the enhancement over alltoallv — the
paper's signature result is enhancement GROWING with P."""
from __future__ import annotations

import time

import numpy as np

from repro.core import protocols as proto
from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution

PARTS = [4, 8, 16, 32]


def run(n: int = 8000):
    x = make_distribution("sphere", n, seed=7)
    q = np.ones(n) / n
    rows = []
    base_t = None
    for P in PARTS:
        t0 = time.time()
        res = run_distributed_fmm(x, q, nparts=P, method="orb",
                                  protocol="hsdx", check_delivery=False,
                                  ncrit=64)
        wall_us = (time.time() - t0) * 1e6
        B, boxes = res.bytes_matrix, None
        t_hsdx = res.loggp_time
        a2a = proto.make_schedule("alltoallv", B)
        t_a2a = proto.loggp_time(a2a)
        if base_t is None:
            base_t = t_hsdx * P  # per-proc work reference
        speedup = base_t / (t_hsdx * PARTS[0])
        enh = (t_a2a - t_hsdx) / t_a2a * 100.0
        rows.append((f"table3_hsdx_P{P}", wall_us,
                     f"hsdx_ms={t_hsdx*1e3:.3f};a2a_ms={t_a2a*1e3:.3f};"
                     f"enhancement={enh:.1f}%;stages={res.n_stages}"))
    return rows


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.host_side import write_bench_json
    json_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_table3_hsdx.json")
    for a in sys.argv[1:]:
        if a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--no-json":
            json_path = None
    rows = run(n=int(os.environ.get("TABLE3_N", "8000")))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    if json_path:
        where = write_bench_json(rows, json_path,
                                 meta={"module": "table3_hsdx"})
        print(f"# wrote {where}", file=sys.stderr)
