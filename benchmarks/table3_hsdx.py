"""Table 3: HSDX strong-scaling vs MPI_Alltoallv.

The paper scales 4k -> 64k cores on Shaheen; offline we scale the partition
count on a fixed problem, build the exact per-pair LET byte matrices, and
compare the LogGP-modeled exchange times.  derived mirrors the table rows:
relative speedup, efficiency, and the enhancement over alltoallv — the
paper's signature result is enhancement GROWING with P."""
from __future__ import annotations

import time

import numpy as np

from repro.core import protocols as proto
from repro.core.distributed_fmm import run_distributed_fmm
from repro.core.distributions import make_distribution

PARTS = [4, 8, 16, 32]


def run(n: int = 8000):
    x = make_distribution("sphere", n, seed=7)
    q = np.ones(n) / n
    rows = []
    base_t = None
    for P in PARTS:
        t0 = time.time()
        res = run_distributed_fmm(x, q, nparts=P, method="orb",
                                  protocol="hsdx", check_delivery=False,
                                  ncrit=64)
        wall_us = (time.time() - t0) * 1e6
        B, boxes = res.bytes_matrix, None
        t_hsdx = res.loggp_time
        a2a = proto.make_schedule("alltoallv", B)
        t_a2a = proto.loggp_time(a2a)
        if base_t is None:
            base_t = t_hsdx * P  # per-proc work reference
        speedup = base_t / (t_hsdx * PARTS[0])
        enh = (t_a2a - t_hsdx) / t_a2a * 100.0
        rows.append((f"table3_hsdx_P{P}", wall_us,
                     f"hsdx_ms={t_hsdx*1e3:.3f};a2a_ms={t_a2a*1e3:.3f};"
                     f"enhancement={enh:.1f}%;stages={res.n_stages}"))
    return rows
