"""Fig 8 (exchange): measured multi-device LET exchange vs LogGP prediction.

The three `repro.core.dist` collective programs — bulk `all_to_all`,
granularity-tuned `ppermute` rounds, and the HSDX relay — run on virtual
host devices in a subprocess (so this process keeps a single device) and
are timed warm against `predicted_time`'s LogGP cost of the *same*
`protocols.Schedule` the program executes.  derived = measured vs modeled
ms, rounds, and moved/delivered wire bytes per protocol.

Results also land in benchmarks/BENCH_exchange.json (schema repro-bench-v1).

Toy-size smoke (CI):
  FIG8X_N=800 FIG8X_PARTS=8 FIG8X_REPS=5 python benchmarks/fig8_exchange.py
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%(devices)d")
    import json
    import time
    import numpy as np
    from repro.core.api import PartitionSpec, plan_geometry
    from repro.core.dist import DIST_PROTOCOLS, ShardedEngine
    from repro.launch.mesh import host_device_mesh

    rng = np.random.default_rng(5)
    x = rng.uniform(0, 1, (%(n)d, 3))
    x[:, 0] *= 4.0                       # stretched slab: HSDX must relay
    q = rng.uniform(-1, 1, %(n)d)
    geo = plan_geometry(x, q, PartitionSpec(nparts=%(nparts)d,
                                            method="morton", ncrit=64))
    mesh = host_device_mesh(%(devices)d)
    eng = ShardedEngine(geo, mesh)
    rows = []
    for p in DIST_PROTOCOLS:
        fn = eng.exchange_fn(p)
        fn().block_until_ready()         # compile + first launch
        t0 = time.perf_counter()
        for _ in range(%(reps)d):
            out = fn()
        out.block_until_ready()
        dt = (time.perf_counter() - t0) / %(reps)d
        st = eng.exchange_stats(p)
        rows.append(dict(protocol=p, measured_s=dt,
                         loggp_s=st["loggp_time"],
                         n_rounds=st["n_rounds"],
                         moved_bytes=st["moved_bytes"],
                         delivered_bytes=st["delivered_bytes"],
                         padded_wire_bytes=st["padded_wire_bytes"]))
    print(json.dumps(rows))
""").strip()


def run(n: int | None = None, nparts: int | None = None,
        devices: int | None = None, reps: int | None = None):
    n = n or int(os.environ.get("FIG8X_N", 4000))
    nparts = nparts or int(os.environ.get("FIG8X_PARTS", 8))
    devices = devices or int(os.environ.get("FIG8X_DEVICES", 4))
    reps = reps or int(os.environ.get("FIG8X_REPS", 20))
    script = _SCRIPT % dict(n=n, nparts=nparts, devices=devices, reps=reps)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"fig8_exchange subprocess failed:\n"
                           f"{out.stderr[-3000:]}")
    results = json.loads(out.stdout.strip().splitlines()[-1])
    rows = []
    for r in results:
        derived = (f"loggp={r['loggp_s']*1e3:.3f}ms;"
                   f"rounds={r['n_rounds']};"
                   f"moved={r['moved_bytes']}B;"
                   f"delivered={r['delivered_bytes']}B;"
                   f"padded_wire={r['padded_wire_bytes']}B")
        rows.append((f"fig8_exchange_{r['protocol']}_D{devices}",
                     r["measured_s"] * 1e6, derived))
    from benchmarks.host_side import write_bench_json
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_exchange.json")
    write_bench_json(rows, path, meta=dict(n=n, nparts=nparts,
                                           devices=devices, reps=reps))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}", flush=True)
